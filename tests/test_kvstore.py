"""Hierarchical KV store subsystem (repro.core.kvstore, docs/kv_store.md):
tier caches and the tiered store facade, allocator demotion/promotion
invariants (a live-referenced block is never lost; a promoted prefix is
bit-for-bit re-matchable), shared-store chain-hash keying, the typed
`import_handoff` block-size error + resident-block dedup, the FIFO
shared-NIC `LinkContentionModel` behind chunked handoff streaming,
workflow-aware affinity routing (wire field -> ring pinning -> fallback
chain), the `KVStoreSpec`/observability spec plumbing through deployments
and the Metrics Gateway, tenancy token refunds + adaptive retry_after,
and twin-run determinism of the tiered serving scenario.

CI runs this file in the isolated-first slot (see .github/workflows)."""
import pytest

from repro import configs
from repro.api import (AdminClient, APIStatusError, ChatCompletionRequest,
                       ChatMessage, CompletionRequest, ServingClient)
from repro.api.errors import APIError
from repro.config import ServiceConfig
from repro.core.autoscaler import AlertRule, rule_from_dict
from repro.core.controller import ClusterSpec, ControlPlane
from repro.core.deployments import ModelDeploymentSpec
from repro.core.kvstore import (KVStoreSpec, LinkContentionModel, TierCache,
                                TieredKVStore, chunk_plan, make_tier_store)
from repro.core.router import WorkflowAffinity
from repro.core.tenancy import TenancyManager, TenantSpec
from repro.engine.kv_cache import (BlockAllocator, HandoffBlockSizeMismatch,
                                   SequenceKV, chain_hash, export_handoff,
                                   import_handoff)
from repro.engine.request import Request, RequestStatus, SamplingParams

MODEL = "smollm-135m"


# ---------------------------------------------------------------------------
# TierCache / TieredKVStore units
# ---------------------------------------------------------------------------

def test_tier_cache_lru_eviction_and_counters():
    tc = TierCache(2, name="host")
    assert tc.put(1) and tc.put(2)
    assert 1 in tc and 2 in tc and len(tc) == 2
    tc.get(1)                      # refresh: 2 becomes LRU
    tc.put(3)                      # evicts 2
    assert 2 not in tc and 1 in tc and 3 in tc
    assert tc.evictions == 1 and tc.insertions == 3
    assert tc.hits == 1
    assert not tc.get(2) and tc.misses == 1
    # re-putting a resident key refreshes without counting an insertion
    tc.put(1)
    assert tc.insertions == 3 and len(tc) == 2
    # a zero-capacity tier stores nothing
    off = TierCache(0)
    assert not off.put(9) and 9 not in off


def test_tiered_store_write_through_and_promotion_path():
    shared = TierCache(8, name="shared")
    ts = TieredKVStore(TierCache(8, name="host"), shared=shared)
    ts.demote(11)
    # write-through: the demotion lands in BOTH lower tiers
    assert 11 in ts.host and 11 in shared
    assert ts.demotions == 1
    assert ts.lookup(11) and ts.host_hits == 1
    # a hash only the shared store holds (demoted by a sibling engine) is
    # pulled up into the host tier on the way back — inclusive hierarchy
    shared.put(22)
    assert ts.lookup(22)
    assert ts.shared_hits == 1 and 22 in ts.host
    assert not ts.lookup(33)


def test_shared_store_keys_collide_only_on_identical_chains():
    # chain hashes are content addresses over the FULL token prefix: two
    # workflows sharing a context produce the same key for the shared
    # part and distinct keys from the first divergent block on
    bs = 4
    common = tuple(range(1, 1 + bs))
    h0 = chain_hash(0, common)
    assert h0 == chain_hash(0, common)
    a = chain_hash(h0, (9, 9, 9, 9))
    b = chain_hash(h0, (8, 8, 8, 8))
    assert a != b
    # same chunk under a different predecessor chain is a different key
    assert chain_hash(a, common) != chain_hash(b, common)
    shared = TierCache(16, name="shared")
    shared.put(a), shared.put(b)
    assert len(shared) == 2
    shared.put(chain_hash(h0, (9, 9, 9, 9)))     # identical chain: no dup
    assert len(shared) == 2


def test_make_tier_store_disabled_forms():
    assert make_tier_store(None) is None
    assert make_tier_store(KVStoreSpec(host_blocks=0, shared_blocks=0)) \
        is None
    # shared-only: a deployment can pool everything in the shared store
    shared = TierCache(4, name="shared")
    ts = make_tier_store(KVStoreSpec(host_blocks=0), shared=shared)
    assert ts is not None and ts.shared is shared


def test_kvstore_spec_validate_and_roundtrip():
    spec = KVStoreSpec(host_blocks=128, shared_blocks=1024)
    spec.validate()
    assert KVStoreSpec.from_dict(spec.to_dict()) == spec
    with pytest.raises(APIStatusError) as ei:
        KVStoreSpec(host_blocks=-1).validate()
    assert ei.value.error.param == "kv_store.host_blocks"
    with pytest.raises(APIStatusError) as ei:
        KVStoreSpec.from_dict({"host_blocks": 1, "hbm_blocks": 2})
    assert "hbm_blocks" in ei.value.error.param


# ---------------------------------------------------------------------------
# allocator tiering invariants
# ---------------------------------------------------------------------------

def _filled_allocator(num_blocks=8, bs=4, tiers=True):
    alloc = BlockAllocator(num_blocks, bs)
    if tiers:
        alloc.tier_store = TieredKVStore(
            TierCache(64, name="host"), shared=TierCache(64, name="shared"))
    return alloc


def test_demotion_never_loses_live_referenced_block():
    alloc = _filled_allocator()
    # one LIVE sealed block and three warm (evictable) sealed blocks
    live = alloc.allocate()
    alloc.seal(live, 101)
    warm = []
    for h in (102, 103, 104):
        i = alloc.allocate()
        alloc.seal(i, h)
        alloc.free(i)               # ref 0 + sealed -> evictable pool
        warm.append(i)
    # burn every remaining block so allocation must recycle the warm pool
    for _ in range(alloc.num_blocks - 4 + len(warm)):
        alloc.allocate()
    alloc.check_invariants()
    # the warm blocks were demoted — never the live one
    ts = alloc.tier_store
    assert ts.demotions == 3
    assert all(h in ts.host and h in ts.shared for h in (102, 103, 104))
    assert 101 not in ts.host
    assert alloc.blocks[live].token_hash == 101
    assert alloc.prefix_index[101] == live
    with pytest.raises(Exception):
        alloc.allocate()            # and a held block is never recycled


def test_promotion_restores_match_prefix_bit_for_bit():
    bs = 4
    alloc = _filled_allocator(num_blocks=8, bs=bs)
    tokens = list(range(1, 2 * bs + 2))       # 2 complete blocks + 1 token
    seq = SequenceKV(alloc)
    assert seq.match_prefix(tokens) == 0
    seq.append_tokens(len(tokens), token_ids=tokens)
    seq.release()                             # sealed blocks stay warm
    # evict everything: churn allocations until the warm pool is recycled
    held = [alloc.allocate() for _ in range(alloc.num_blocks)]
    assert alloc.tier_store.demotions >= 2
    for i in held:
        alloc.free(i)
    baseline_hits = alloc.prefix_hits
    # the prompt's blocks are HBM-gone but tier-resident: match_prefix
    # promotes them back and covers exactly the complete-block prefix,
    # token-for-token the same coverage a pure-HBM hit would give
    seq2 = SequenceKV(alloc)
    assert seq2.match_prefix(tokens) == 2 * bs
    assert alloc.tier_store.promotions == 2
    assert alloc.prefix_hits == baseline_hits + 2
    alloc.check_invariants()
    # and the promoted blocks are genuinely live again
    assert all(alloc.blocks[i].ref_count == 1 for i in seq2.block_table)


def test_promotion_without_tiers_or_free_blocks_is_a_miss():
    bs = 4
    alloc = BlockAllocator(4, bs)             # no tier store
    seq = SequenceKV(alloc)
    tokens = list(range(1, bs + 2))
    seq.append_tokens(len(tokens), token_ids=tokens)
    seq.release()
    held = [alloc.allocate() for _ in range(alloc.num_blocks)]
    assert SequenceKV(alloc).match_prefix(tokens) == 0    # discarded
    for i in held:
        alloc.free(i)
    # tiers present but zero free blocks: promotion refuses to evict the
    # warm pool for a speculative hit
    alloc2 = _filled_allocator(num_blocks=2, bs=bs)
    s = SequenceKV(alloc2)
    s.append_tokens(len(tokens), token_ids=tokens)
    s.release()
    burn = [alloc2.allocate() for _ in range(2)]
    assert alloc2.tier_store.demotions >= 1
    assert SequenceKV(alloc2).match_prefix(tokens) == 0
    assert alloc2.tier_store.promotions == 0
    for i in burn:
        alloc2.free(i)
    alloc2.check_invariants()


# ---------------------------------------------------------------------------
# import_handoff edge cases (satellite: typed error + dedup)
# ---------------------------------------------------------------------------

def test_import_handoff_block_size_mismatch_is_typed():
    h = export_handoff(list(range(1, 65)), block_size=16, first_token=1)
    alloc = BlockAllocator(16, 32)
    with pytest.raises(HandoffBlockSizeMismatch) as ei:
        import_handoff(alloc, h)
    assert ei.value.expected == 32 and ei.value.got == 16
    assert isinstance(ei.value, ValueError)
    # nothing was sealed by the failed import
    assert not alloc.prefix_index
    # caching off still reports a plain zero (no hashes to seal at all)
    off = BlockAllocator(16, 16, enable_prefix_caching=False)
    assert import_handoff(off, h) == 0


def test_import_handoff_dedups_resident_partial_prefix():
    toks = list(range(1, 129))
    short = export_handoff(toks[:64], block_size=16, first_token=1)
    full = export_handoff(toks, block_size=16, first_token=1)
    alloc = BlockAllocator(64, 16)
    assert import_handoff(alloc, short) == 3     # (64-1)//16 complete blocks
    q0, h0 = alloc.prefix_queries, alloc.prefix_hits
    # the longer handoff shares its first 3 chain hashes with the resident
    # prefix: only the new suffix blocks are imported, and the dedup walk
    # leaves the routing-visible hit-rate counters untouched
    assert import_handoff(alloc, full) == 4
    assert (alloc.prefix_queries, alloc.prefix_hits) == (q0, h0)
    assert import_handoff(alloc, full) == 0          # fully resident
    alloc.check_invariants()
    seq = SequenceKV(alloc)
    assert seq.match_prefix(toks + [999]) == 112     # all 7 resident blocks


# ---------------------------------------------------------------------------
# LinkContentionModel / chunk_plan
# ---------------------------------------------------------------------------

def test_link_contention_fifo_reservation():
    link = LinkContentionModel(100.0)          # 100 B/s
    # two "simultaneous" transfers serialise: 50B then 30B
    assert link.transmit(50, 10.0) == pytest.approx(10.5)
    assert link.transmit(30, 10.0) == pytest.approx(10.8)
    assert link.queue_delay_total == pytest.approx(0.5)
    assert link.transfers == 2 and link.bytes_sent == 80.0
    # after the link drains, a new transfer starts immediately
    assert link.transmit(10, 20.0) == pytest.approx(20.1)
    # zero-byte and zero-bandwidth transfers complete instantly
    assert link.transmit(0, 30.0) == 30.0
    assert LinkContentionModel(0.0).transmit(100, 5.0) == 5.0
    st = link.stats()
    assert st["transfers"] == 3 and st["bandwidth"] == 100.0


def test_chunk_plan_shapes():
    assert chunk_plan(80.0, 8) == [10.0] * 8
    assert sum(chunk_plan(100.0, 3)) == pytest.approx(100.0)
    assert chunk_plan(0.0, 4) == [0.0] * 4
    assert chunk_plan(64.0, 0) == [64.0]       # always >= 1 chunk
    assert chunk_plan(64.0, 1) == [64.0]       # the atomic baseline


# ---------------------------------------------------------------------------
# workflow-aware affinity routing
# ---------------------------------------------------------------------------

def _eps(n=4):
    return [{"id": i, "node": f"node{i:03d}", "port": 8000 + i,
             "phase": None} for i in range(n)]


def _req(workflow=None, session=None, tenant=None):
    r = Request(prompt_tokens=[1, 2, 3],
                sampling=SamplingParams(target_output_len=2,
                                        max_new_tokens=2),
                session_id=session, workflow_id=workflow)
    r.tenant = tenant
    return r


def test_workflow_affinity_pins_stages_to_one_instance():
    pol = WorkflowAffinity()
    eps = _eps()
    picks = {pol.select(eps, _req(workflow="wf-7"))["port"]
             for _ in range(10)}
    assert len(picks) == 1                     # every stage, same instance
    assert pol.affinity_hits == 10
    # survives endpoint churn for most keys (consistent hashing): the
    # pinned endpoint only moves if ITS vnode range changed
    spread = {w: pol.select(eps, _req(workflow=f"wf-{w}"))["port"]
              for w in range(32)}
    moved = sum(1 for w, p in spread.items()
                if pol.select(eps[:-1], _req(workflow=f"wf-{w}"))
                .get("port") != p and p != eps[-1]["port"])
    assert moved == 0                          # only the dead node's keys


def test_workflow_affinity_fallback_chain_and_tenant_namespacing():
    pol = WorkflowAffinity()
    eps = _eps()
    # no workflow_id -> session affinity pins by session
    s = {pol.select(eps, _req(session="chat-1"))["port"] for _ in range(5)}
    assert len(s) == 1 and pol.fallbacks == 5
    assert pol.stats()["session_fallback"]["affinity_hits"] == 5
    # neither key -> round-robin sweeps the fleet
    anon = [pol.select(eps, _req())["port"] for _ in range(4)]
    assert sorted(anon) == sorted(e["port"] for e in eps)
    # tenant namespacing: the same workflow id from two tenants is two
    # independent ring keys (they *may* collide on an endpoint, but the
    # ring keys must hash independently — check against a bigger ring)
    many = _eps(8)
    picks = {t: pol.select(many, _req(workflow="wf-1", tenant=t))["port"]
             for t in ("uni-a", "uni-b", "uni-c", "uni-d", "uni-e")}
    assert len(set(picks.values())) > 1


def test_workflow_id_wire_roundtrip():
    c = CompletionRequest(model=MODEL, prompt=[1, 2, 3], max_tokens=4,
                          workflow_id="wf-1", session_id="s-1")
    c.validate()
    assert CompletionRequest.from_dict(c.to_dict()).workflow_id == "wf-1"
    assert c.to_engine_request().workflow_id == "wf-1"
    assert CompletionRequest.from_engine(
        c.to_engine_request(), MODEL).workflow_id == "wf-1"
    m = ChatCompletionRequest(model=MODEL,
                              messages=[ChatMessage("user", [1, 2])],
                              workflow_id="wf-2")
    m.validate()
    assert ChatCompletionRequest.from_dict(m.to_dict()).workflow_id == "wf-2"
    assert m.to_engine_request().workflow_id == "wf-2"


# ---------------------------------------------------------------------------
# spec plumbing: deployments, metrics gateway, autoscaler overrides
# ---------------------------------------------------------------------------

RULE = {"name": "hot_kv", "metric": "kv_util_avg", "op": "gt",
        "threshold": 0.9, "for_duration": 20.0, "delta": 1,
        "cooldown": 30.0, "pool": None}


def test_deployment_spec_kv_and_observability_roundtrip():
    spec = ModelDeploymentSpec(
        model=MODEL, kv_store=KVStoreSpec(host_blocks=64, shared_blocks=256),
        prometheus_labels={"team": "chat-ai", "cluster": "hpc1"},
        alert_rules=[dict(RULE)])
    spec.validate()
    again = ModelDeploymentSpec.from_dict(spec.to_dict())
    assert again.kv_store == spec.kv_store
    assert again.prometheus_labels == spec.prometheus_labels
    assert again.alert_rules == spec.alert_rules


@pytest.mark.parametrize("patch,param", [
    (dict(kv_store="big"), "kv_store"),
    (dict(prometheus_labels={"team": 3}), "prometheus_labels.team"),
    (dict(prometheus_labels={"": "x"}), "prometheus_labels."),
    (dict(alert_rules=[{**RULE, "op": "ge"}]), "alert_rules[0].op"),
    (dict(alert_rules=[{**RULE, "bogus": 1}]), "alert_rules[0].bogus"),
    (dict(alert_rules=[{k: v for k, v in RULE.items() if k != "metric"}]),
     "alert_rules[0].metric"),
    (dict(alert_rules=[{**RULE, "pool": "middle"}]), "alert_rules[0].pool"),
    (dict(alert_rules=[{**RULE, "threshold": "hot"}]),
     "alert_rules[0].threshold"),
])
def test_deployment_spec_kv_validation_is_field_addressed(patch, param):
    spec = ModelDeploymentSpec(model=MODEL, **patch)
    with pytest.raises(APIStatusError) as ei:
        spec.validate()
    assert ei.value.error.param == param


def test_rule_from_dict_builds_equivalent_rule():
    rule = rule_from_dict(RULE)
    assert isinstance(rule, AlertRule)
    assert rule.name == "hot_kv" and rule.metric == "kv_util_avg"
    assert rule.breached(0.95) and not rule.breached(0.5)
    defaults = rule_from_dict({k: v for k, v in RULE.items()
                               if k not in ("cooldown", "pool")})
    assert defaults.cooldown == 60.0 and defaults.pool is None


def _tiered_plane(**kw):
    spec = ClusterSpec(num_nodes=4, gpus_per_node=1, max_num_seqs=8,
                       num_blocks=kw.pop("num_blocks", 64), block_size=16,
                       max_model_len=1024, services=ServiceConfig(
                           routing_policy="workflow_affinity"))
    cp = ControlPlane(spec, alert_rules=[])
    cp.add_tenant("uni", "sk-test")
    cp.register_model(configs.get(MODEL))
    AdminClient(cp).apply(ModelDeploymentSpec(
        model=MODEL, replicas=kw.pop("replicas", 2), max_replicas=4,
        routing_policy="workflow_affinity", est_load_time=10.0,
        kv_store=KVStoreSpec(host_blocks=256, shared_blocks=1024),
        prometheus_labels={"team": "chat-ai"},
        alert_rules=[dict(RULE)], **kw))
    cp.run_until(120.0)
    assert len(cp.ready_endpoints(MODEL)) >= 2
    return cp


def test_control_plane_wires_tiers_labels_and_rule_overrides():
    cp = _tiered_plane()
    insts = [i for i in cp.instances_spawned if i.alive]
    stores = [i.engine.allocator.tier_store for i in insts]
    assert all(ts is not None for ts in stores)
    # every replica has a PRIVATE host tier but the SAME shared store
    assert len({id(ts.host) for ts in stores}) == len(stores)
    assert len({id(ts.shared) for ts in stores}) == 1
    assert cp.shared_kv[MODEL] is stores[0].shared
    # prometheus targets carry the deployment's extra labels; core labels
    # are not overridable
    targets = cp.metrics_gateway.prometheus_targets()
    assert targets and all(t["labels"]["team"] == "chat-ai"
                           for t in targets)
    assert all(t["labels"]["model"] == MODEL for t in targets)
    # the autoscaler resolves the deployment's override rule set
    cfg_id = cp.db["ai_model_configurations"].select(
        model_name=MODEL)[0]["id"]
    override = cp.autoscaler.rules_for(cfg_id)
    assert [r.name for r in override] == ["hot_kv"]
    assert cp.autoscaler.rules_for(cfg_id + 999) is None
    # per-tier series land in the scrape aggregates
    cp.run_until(cp.loop.now + 30.0)
    assert cp.metrics_gateway.series(cfg_id, "kv_demotions_total", 0.0)
    assert cp.metrics_gateway.series(cfg_id, "kv_promotions_total", 0.0)


def test_tiered_serving_end_to_end_promotes_across_requests():
    cp = _tiered_plane(num_blocks=32, replicas=2)
    client = ServingClient(cp, api_key="sk-test")
    prompt = list(range(1, 200))
    for i in range(6):
        # same workflow -> same instance; interleaved filler churns the
        # tiny HBM pool so the transcript's blocks get demoted + promoted
        client.completions(model=MODEL, prompt=prompt, max_tokens=2,
                           target_output_len=2,
                           workflow_id="wf-0").result(max_wait=600.0)
        client.completions(model=MODEL,
                           prompt=[7000 + 17 * i + j for j in range(150)],
                           max_tokens=2, target_output_len=2,
                           workflow_id=f"filler-{i}").result(max_wait=600.0)
    stores = [i.engine.allocator.tier_store
              for i in cp.instances_spawned if i.alive]
    assert sum(ts.demotions for ts in stores) > 0
    assert sum(ts.promotions for ts in stores) > 0
    snaps = [i.metrics_snapshot()
             for i in cp.instances_spawned if i.alive]
    assert sum(s["kv_demotions_total"] for s in snaps) > 0
    assert sum(s["kv_promotions_total"] for s in snaps) > 0


# ---------------------------------------------------------------------------
# tenancy satellites: early-stop refunds + adaptive retry_after
# ---------------------------------------------------------------------------

def _tenancy(spec):
    cp = ControlPlane(ClusterSpec(num_nodes=1))
    cp.add_tenant("uni", "sk-test", spec=spec)
    return cp.tenancy


def _done_req(target=100, completion=10, prompt=16):
    r = Request(prompt_tokens=[1] * prompt,
                sampling=SamplingParams(target_output_len=target,
                                        max_new_tokens=target))
    r.status = RequestStatus.FINISHED
    r.metrics.finish_time = 1.0
    r.metrics.prompt_tokens = prompt
    r.metrics.completion_tokens = completion
    return r


def test_early_stop_refunds_token_bucket():
    tm = _tenancy(TenantSpec(name="uni", tokens_per_min=6000.0))
    tb = tm._tok_buckets["uni"]
    r = _done_req(target=100, completion=10, prompt=16)
    assert tm.admit("uni", r, now=0.0) is None
    assert tb.level == pytest.approx(6000.0 - 116)
    tm.on_request_done("uni", r, now=1.0)
    # admission charged prompt+target (116); the engine recorded 16+10 —
    # the 90-token surplus flows back (refill for the elapsed second is
    # capped by the bucket's level accounting, checked loosely here)
    assert tb.level >= 6000.0 - 26
    # the refund never overfills the bucket
    assert tb.level <= tb.capacity
    # usage metering still bills the REAL tokens
    assert tm.totals["uni"]["completion_tokens"] == 10


def test_full_length_completion_refunds_nothing():
    tm = _tenancy(TenantSpec(name="uni", tokens_per_min=6000.0))
    tb = tm._tok_buckets["uni"]
    r = _done_req(target=100, completion=100, prompt=16)
    assert tm.admit("uni", r, now=0.0) is None
    level_after_admit = tb.level
    tm.on_request_done("uni", r, now=0.0)      # same instant: no refill
    assert tb.level == pytest.approx(level_after_admit)


def test_max_inflight_retry_after_tracks_completion_rate():
    tm = _tenancy(TenantSpec(name="uni", max_inflight=1))
    r1 = _done_req()
    assert tm.admit("uni", r1, now=0.0) is None
    err = tm.admit("uni", _done_req(), now=0.0)
    assert isinstance(err, APIError) and err.http_status == 429
    assert err.retry_after == 1.0              # no completions observed yet
    # observe a steady ~2 s completion cadence
    tm.on_request_done("uni", r1, now=0.0)
    for t in (2.0, 4.0, 6.0, 8.0):
        r = _done_req()
        assert tm.admit("uni", r, now=t) is None
        tm.on_request_done("uni", r, now=t)
    blocked = _done_req()
    assert tm.admit("uni", blocked, now=8.0) is None
    err = tm.admit("uni", _done_req(), now=8.0)
    assert err is not None and err.retry_after == pytest.approx(2.0)
    # the hint is clamped to a sane window
    tm._done_gap["uni"] = 1e6
    err = tm.admit("uni", _done_req(), now=8.0)
    assert err.retry_after == 60.0
    tm._done_gap["uni"] = 1e-6
    err = tm.admit("uni", _done_req(), now=8.0)
    assert err.retry_after == 0.05


# ---------------------------------------------------------------------------
# chunked handoff streaming + twin-run determinism (integration)
# ---------------------------------------------------------------------------

def test_chunked_streaming_charges_through_the_shared_link():
    from benchmarks.disagg import run_scenario
    from benchmarks.table1 import MODEL as BENCH_MODEL
    row = run_scenario("disaggregated", 4, total=2, prefill=1)
    assert row["handoffs"] >= 4
    assert row["transfer_mean_ms"] > 0
    links = row["router"]["kv_links"]
    assert BENCH_MODEL in links
    st = links[BENCH_MODEL]
    # every handoff moved its payload through the contention model in
    # stream_chunks pieces (the deployment default is 8)
    assert st["transfers"] == row["handoffs"] * 8
    assert st["bytes_sent"] > 0 and st["queue_delay_total"] >= 0.0


def test_kvstore_twin_runs_bit_identical():
    from benchmarks.kvstore import run_tiering
    a = run_tiering(4, True, sanitize=True)
    b = run_tiering(4, True, sanitize=True)
    assert a["trace_digest"] == b["trace_digest"], \
        "same tiered scenario, different event trace — nondeterminism"
    assert a["events_run"] == b["events_run"]
    assert a["prefix_hit_rate"] == b["prefix_hit_rate"]
    assert a["promotions"] == b["promotions"]
    assert a["failed"] == 0
