"""Autoscaler edge cases (paper §3.3): cooldown refractory period, breaches
that clear before `for_duration`, metric-less history samples, and the
beyond-paper idle scale-down rule never killing a config's last instance.

Uses a stub metrics gateway (plain deques) so each case runs in
microseconds of wall time with exact control over the scrape series."""
from collections import defaultdict, deque

from repro.core.autoscaler import (AlertRule, Autoscaler,
                                   GATEWAY_QUEUE_SCALE_UP, IDLE_SCALE_DOWN,
                                   QUEUE_TIME_SCALE_UP)
from repro.core.db import Database
from repro.core.metrics_gateway import MetricsGateway
from repro.core.simclock import EventLoop


class StubGateway:
    """history + series + webhook capture, nothing else."""

    def __init__(self):
        self.history = defaultdict(deque)
        self.webhooks = []

    def series(self, config_id, metric, since):
        return [(t, m[metric]) for t, m in self.history[config_id]
                if t >= since and metric in m]

    def grafana_webhook(self, payload):
        self.webhooks.append(dict(payload, t=self._now))
        return 200


def drive(rule, samples, eval_interval=10.0, until=400.0):
    """Feed (t, metrics-dict) samples into a fresh Autoscaler run."""
    gw = StubGateway()
    loop = EventLoop()
    scaler = Autoscaler(gw, loop, rules=[rule], eval_interval=eval_interval)
    for t, m in samples:
        loop.call_at(t, lambda t=t, m=m: gw.history[1].append((t, m)))

    def _track():
        gw._now = loop.now
    loop.every(1.0, lambda now: _track())
    gw._now = 0.0
    loop.run_until(until)
    return gw, scaler


def qt(v):
    return {"queue_time_max": v}


def test_sustained_breach_fires_once_per_cooldown():
    rule = AlertRule("qt", "queue_time_max", "gt", 5.0, for_duration=30.0,
                     delta=+1, cooldown=100.0)
    # breach continuously for 400 s, sampled every 5 s
    samples = [(float(t), qt(9.0)) for t in range(0, 400, 5)]
    gw, scaler = drive(rule, samples)
    fire_times = [t for t, _, _ in scaler.fired]
    assert len(fire_times) >= 2
    # refractory period respected between consecutive fires
    gaps = [b - a for a, b in zip(fire_times, fire_times[1:])]
    assert all(g >= rule.cooldown for g in gaps), gaps
    # and the first fire waited out for_duration
    assert fire_times[0] >= 30.0


def test_breach_clearing_before_for_duration_never_fires():
    rule = AlertRule("qt", "queue_time_max", "gt", 5.0, for_duration=30.0,
                     delta=+1, cooldown=60.0)
    # 20 s spikes separated by recovery: no window of 30 sustained seconds
    samples = []
    for t in range(0, 400, 5):
        breach = (t % 50) < 20
        samples.append((float(t), qt(9.0 if breach else 1.0)))
    gw, scaler = drive(rule, samples)
    assert scaler.fired == []
    assert gw.webhooks == []


def test_pending_breach_resets_after_clear():
    rule = AlertRule("qt", "queue_time_max", "gt", 5.0, for_duration=30.0,
                     delta=+1, cooldown=60.0)
    # 25 s breach, 10 s clear, then a 35 s breach -> exactly one fire, and
    # only from the second episode (the first 25 s must not carry over)
    samples = []
    for t in range(0, 25, 5):
        samples.append((float(t), qt(9.0)))
    for t in range(25, 35, 5):
        samples.append((float(t), qt(0.5)))
    for t in range(35, 75, 5):
        samples.append((float(t), qt(9.0)))
    gw, scaler = drive(rule, samples, until=120.0)
    assert len(scaler.fired) == 1
    assert scaler.fired[0][0] >= 65.0     # 35 + for_duration


def test_missing_metric_samples_are_skipped_not_zero_filled():
    # partial samples (gateway-queue only) must not satisfy or break an
    # engine-metric rule
    rule = AlertRule("idle", "kv_util_avg", "lt", 0.02, for_duration=30.0,
                     delta=-1, cooldown=60.0)
    samples = [(float(t), {"gateway_queued": 3, "queue_time_max": 8.0})
               for t in range(0, 200, 5)]
    gw, scaler = drive(rule, samples, until=200.0)
    assert scaler.fired == []


def test_gateway_queue_rule_fires_on_partial_samples():
    samples = [(float(t), {"gateway_queued": 4, "queue_time_max": 12.0})
               for t in range(0, 100, 5)]
    gw, scaler = drive(GATEWAY_QUEUE_SCALE_UP, samples, until=100.0)
    assert scaler.fired
    assert gw.webhooks[0]["delta"] == +1


def test_default_rules_include_gateway_queue():
    gw = StubGateway()
    scaler = Autoscaler(gw, EventLoop())
    names = {r.name for r in scaler.rules}
    assert QUEUE_TIME_SCALE_UP.name in names
    assert GATEWAY_QUEUE_SCALE_UP.name in names
    assert IDLE_SCALE_DOWN.name in names


# ---------------------------------------------------------------------------
# actuation clamps (MetricsGateway webhook side)
# ---------------------------------------------------------------------------

def mk_gateway(instances):
    db = Database()
    loop = EventLoop()
    gw = MetricsGateway(db, loop, registry={}, min_instances=1,
                        max_instances=4)
    cfg = db["ai_model_configurations"].insert(
        db, model_name="m", instances=instances)
    return db, gw, cfg


def test_idle_scale_down_never_kills_last_instance():
    db, gw, cfg = mk_gateway(instances=1)
    code = gw.grafana_webhook({"config_id": cfg["id"], "delta": -1,
                               "rule": IDLE_SCALE_DOWN.name})
    assert code == 200
    assert db["ai_model_configurations"].get(cfg["id"])["instances"] == 1
    assert gw.scale_events == []          # clamped no-op is not an event


def test_scale_down_stops_at_min_then_up_at_max():
    db, gw, cfg = mk_gateway(instances=2)
    gw.grafana_webhook({"config_id": cfg["id"], "delta": -1, "rule": "idle"})
    assert db["ai_model_configurations"].get(cfg["id"])["instances"] == 1
    gw.grafana_webhook({"config_id": cfg["id"], "delta": -1, "rule": "idle"})
    assert db["ai_model_configurations"].get(cfg["id"])["instances"] == 1
    for _ in range(6):
        gw.grafana_webhook({"config_id": cfg["id"], "delta": +1,
                            "rule": "qt"})
    assert db["ai_model_configurations"].get(cfg["id"])["instances"] == 4


def test_webhook_unknown_config_is_404():
    db, gw, cfg = mk_gateway(instances=1)
    assert gw.grafana_webhook({"config_id": 999, "delta": +1,
                               "rule": "qt"}) == 404
