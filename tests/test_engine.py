"""Engine integration tests: paged generation vs dense oracle, preemption,
prefix caching, mixed-batch scheduling, metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TPU_V5E
from repro.engine.engine import LLMEngine
from repro.engine.executor import RealExecutor, SimExecutor
from repro.engine.request import Request, SamplingParams
from repro.models import api


@pytest.fixture(scope="module")
def dense_setup():
    cfg = configs.get("qwen3-1.7b").reduced()
    params, _ = api.init_params(cfg, jax.random.key(7))
    return cfg, params


def oracle_generate(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = api.prefill_fn(params, cfg, {"tokens": toks})
    cache = api.pad_cache(cfg, cache, len(prompt) + n_new + 8)
    out = [int(jnp.argmax(logits[0]))]
    for i in range(n_new - 1):
        pos = jnp.asarray([len(prompt) + i], jnp.int32)
        logits, cache = api.decode_fn(
            params, cfg, jnp.asarray([out[-1]], jnp.int32), cache, pos)
        out.append(int(jnp.argmax(logits[0])))
    return out


def run_engine(eng, reqs, max_steps=2000):
    now = 0.0
    for r in reqs:
        eng.add_request(r, now)
    steps = 0
    while eng.has_work() and steps < max_steps:
        rep = eng.step(now)
        now += max(rep.elapsed, 1e-4)
        steps += 1
    return steps


def test_paged_engine_matches_oracle(dense_setup, rng):
    cfg, params = dense_setup
    # 64-token prompt exercises chunked prefill (max_prefill_tokens=32)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (11, 64)]
    oracle = [oracle_generate(cfg, params, p, 6) for p in prompts]
    ex = RealExecutor(cfg, params, num_blocks=256, block_size=16,
                      hw=TPU_V5E, max_model_len=256, max_slots=8)
    eng = LLMEngine(cfg, ex, num_blocks=256, block_size=16, max_num_seqs=8,
                    max_prefill_tokens=32, max_model_len=256)
    reqs = [Request(prompt_tokens=p,
                    sampling=SamplingParams(temperature=0.0,
                                            max_new_tokens=6))
            for p in prompts]
    run_engine(eng, reqs)
    for r, o in zip(reqs, oracle):
        assert r.status.value == "finished"
        assert r.output_tokens == o
    eng.allocator.check_invariants()
    assert eng.allocator.num_free() == 256


def test_state_executor_matches_oracle(rng):
    """ssm family goes through the slot-state executor, not the paged pool."""
    cfg = configs.get("mamba2-780m").reduced()
    params, _ = api.init_params(cfg, jax.random.key(3))
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (9, 17)]
    oracle = [oracle_generate(cfg, params, p, 5) for p in prompts]
    ex = RealExecutor(cfg, params, num_blocks=64, block_size=16,
                      hw=TPU_V5E, max_model_len=128, max_slots=4)
    eng = LLMEngine(cfg, ex, num_blocks=64, block_size=16, max_num_seqs=4,
                    max_prefill_tokens=64, max_model_len=128,
                    enable_prefix_caching=False)
    reqs = [Request(prompt_tokens=p,
                    sampling=SamplingParams(temperature=0.0,
                                            max_new_tokens=5))
            for p in prompts]
    run_engine(eng, reqs)
    for r, o in zip(reqs, oracle):
        assert r.status.value == "finished"
        assert r.output_tokens == o


def test_preemption_under_block_pressure(dense_setup, rng):
    # 3 seqs prefill into 15/16 blocks; decode growth forces eviction
    cfg, params = dense_setup
    ex = RealExecutor(cfg, params, num_blocks=16, block_size=8, hw=TPU_V5E,
                      max_model_len=96, max_slots=4)
    eng = LLMEngine(cfg, ex, num_blocks=16, block_size=8, max_num_seqs=4,
                    max_prefill_tokens=64, max_model_len=96,
                    enable_prefix_caching=False)
    reqs = [Request(prompt_tokens=list(rng.integers(1, cfg.vocab_size,
                                                    size=40)),
                    sampling=SamplingParams(temperature=0.0,
                                            max_new_tokens=6))
            for _ in range(3)]
    run_engine(eng, reqs)
    assert all(r.status.value == "finished" for r in reqs)
    assert all(len(r.output_tokens) == 6 for r in reqs)
    assert eng.metrics.preemptions > 0, "scenario exerted no block pressure"
    eng.allocator.check_invariants()
    assert eng.allocator.num_free() == 16


def test_prefix_caching_does_not_change_outputs(dense_setup, rng):
    """Same requests with and without prefix caching -> identical tokens
    (shared prompt prefixes make the cache actually fire)."""
    cfg, params = dense_setup
    shared = list(rng.integers(1, cfg.vocab_size, size=32))
    prompts = [shared + list(rng.integers(1, cfg.vocab_size, size=8))
               for _ in range(2)]
    outs = {}
    for caching in (False, True):
        ex = RealExecutor(cfg, params, num_blocks=128, block_size=8,
                          hw=TPU_V5E, max_model_len=128, max_slots=4)
        eng = LLMEngine(cfg, ex, num_blocks=128, block_size=8,
                        max_num_seqs=4, max_prefill_tokens=128,
                        max_model_len=128, enable_prefix_caching=caching)
        reqs = [Request(prompt_tokens=list(p),
                        sampling=SamplingParams(temperature=0.0,
                                                max_new_tokens=4))
                for p in prompts]
        run_engine(eng, reqs)
        outs[caching] = [r.output_tokens for r in reqs]
        if caching:
            assert eng.metrics.tokens_prefilled < sum(len(p)
                                                      for p in prompts)
    assert outs[False] == outs[True]


def test_fcfs_admission_order():
    cfg = configs.get("mistral-small-24b")
    from repro.config import GPU_H100
    ex = SimExecutor(cfg, GPU_H100)
    eng = LLMEngine(cfg, ex, num_blocks=64, block_size=16, max_num_seqs=2,
                    max_prefill_tokens=256, max_model_len=512,
                    enable_prefix_caching=False)
    reqs = [Request(prompt_tokens=[i + 1] * 64,
                    sampling=SamplingParams(target_output_len=4,
                                            max_new_tokens=4))
            for i in range(5)]
    now = 0.0
    for i, r in enumerate(reqs):
        eng.add_request(r, now + i * 1e-3)
    order = []
    while eng.has_work():
        rep = eng.step(now)
        now += max(rep.elapsed, 1e-4)
        for r in reqs:
            if r.metrics.first_scheduled_time is not None \
                    and r.request_id not in order:
                order.append(r.request_id)
    assert order == [r.request_id for r in reqs], "FCFS violated"


def test_oversized_request_fails_cleanly():
    cfg = configs.get("mistral-small-24b")
    from repro.config import GPU_H100
    eng = LLMEngine(cfg, SimExecutor(cfg, GPU_H100), num_blocks=32,
                    block_size=16, max_model_len=128)
    r = Request(prompt_tokens=[1] * 1000,
                sampling=SamplingParams(max_new_tokens=4))
    eng.add_request(r, 0.0)
    eng.step(0.0)
    assert r.status.value == "failed"


def test_engine_metrics_snapshot():
    cfg = configs.get("mistral-small-24b")
    from repro.config import GPU_H100
    eng = LLMEngine(cfg, SimExecutor(cfg, GPU_H100), num_blocks=512,
                    block_size=16, max_model_len=2048)
    for i in range(3):
        eng.add_request(Request(prompt_tokens=[1] * 64,
                                sampling=SamplingParams(
                                    target_output_len=8, max_new_tokens=8)),
                        0.0)
    snap = eng.snapshot(1.0)
    assert snap["num_waiting"] == 3
    assert snap["queue_time"] == 1.0
    now = 0.0
    while eng.has_work():
        now += max(eng.step(now).elapsed, 1e-4)
    snap = eng.snapshot(now)
    assert snap["requests_finished_total"] == 3
    assert snap["tokens_generated_total"] >= 3 * 7
    assert snap["kv_utilization"] >= 0.0
