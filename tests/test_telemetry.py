"""SLO burn-rate telemetry (repro.core.telemetry + repro.api.alerts).

Unit tests cover the mergeable histograms, the rollup rings, the metric
registry, the burn math and the exact pending → firing → resolved
lifecycle on hand-placed virtual times; integration tests drive real
planes: alert admin verbs, 422 validation of alert-rule metric keys,
fast-burn shedding through the gateway, burn-fed pool scaling hints, the
harness shed/missed split, and twin-run determinism of the full alert
timeline.
"""
import pytest

from repro import configs
from repro.api import AdminClient, APIStatusError, ServingClient
from repro.config import SLOTarget, SLO_CLASSES, ServiceConfig
from repro.core.controller import ClusterSpec, ControlPlane
from repro.core.deployments import ModelDeploymentSpec
from repro.core.telemetry import (BURN_KINDS, BurnAlert, HIST_BOUNDS,
                                  KNOWN_METRICS, MergeableHistogram,
                                  METRIC_REGISTRY, RollupStore,
                                  TelemetryStore, known_metric,
                                  metric_error)

MODEL = "smollm-135m"


# ---------------------------------------------------------------------------
# unit: mergeable histograms
# ---------------------------------------------------------------------------

def test_histogram_merge_is_exact():
    a, b, c = MergeableHistogram(), MergeableHistogram(), \
        MergeableHistogram()
    for v in (0.002, 0.5, 3.0):
        a.add(v)
        c.add(v)
    for v in (0.004, 7.0):
        b.add(v)
        c.add(v)
    a.merge(b)
    assert a.counts == c.counts
    assert a.count == c.count == 5
    assert a.sum == pytest.approx(c.sum)


def test_histogram_percentile_is_conservative_bucket_upper_bound():
    h = MergeableHistogram()
    for _ in range(100):
        h.add(0.3)                 # falls in the (0.256, 0.512] bucket
    assert h.percentile(0.5) == pytest.approx(0.512)
    assert h.percentile(0.99) == pytest.approx(0.512)
    assert h.percentile(0.5) >= 0.3           # never under-reports
    assert MergeableHistogram().percentile(0.99) == 0.0


def test_histogram_overflow_bucket():
    h = MergeableHistogram()
    h.add(HIST_BOUNDS[-1] * 10)    # beyond every bound
    assert h.count == 1
    assert h.percentile(0.5) == HIST_BOUNDS[-1]


# ---------------------------------------------------------------------------
# unit: metric registry
# ---------------------------------------------------------------------------

def test_registry_expands_templates_over_closed_vocabularies():
    assert known_metric("slo_burn_fast")
    assert known_metric("queue_time_max_prefill")
    assert known_metric("slo_attainment_interactive")
    assert known_metric("span_engine.decode_p99_ms")
    assert not known_metric("slo_burn_fast_{cls}")   # templates expand
    assert not known_metric("queue_time_max_gpu")


def test_metric_error_suggests_close_matches():
    assert metric_error("slo_burn_fast") is None
    err = metric_error("slo_burn_fst")
    assert "slo_burn_fast" in err and "METRIC_REGISTRY" in err
    err = metric_error("span_engine.deocde_p99_ms")
    assert "span_<kind>" in err      # span families get the kind hint


def test_registry_entries_have_type_and_labels():
    for name, meta in METRIC_REGISTRY.items():
        assert meta["type"] in ("counter", "gauge", "histogram",
                                "exemplars"), name
        assert isinstance(meta["labels"], tuple), name


# ---------------------------------------------------------------------------
# unit: rollup rings
# ---------------------------------------------------------------------------

def test_rollup_counts_by_window():
    r = RollupStore()
    for t in range(0, 60):
        r.record(float(t), MODEL, "interactive", good=(t % 2 == 0))
    good, total, shed = r.counts(MODEL, "interactive", 0.0, 60.0)
    assert (good, total, shed) == (30, 60, 0)
    # a narrow recent window sees only its own slots
    good, total, _ = r.counts(MODEL, "interactive", 50.0, 60.0)
    assert total <= 15 and total >= 10


def test_rollup_ring_forgets_old_epochs():
    r = RollupStore(fine_resolution=1.0, fine_slots=4,
                    coarse_resolution=10.0, coarse_slots=4)
    r.record(0.0, MODEL, "batch", good=True)
    # advance far enough that both rings wrapped past t=0
    r.record(100.0, MODEL, "batch", good=False)
    good, total, _ = r.counts(MODEL, "batch", 0.0, 4.0)
    assert total == 0                      # the t=0 slot was reused
    _good, total, _ = r.counts(MODEL, "batch", 97.0, 101.0)
    assert total == 1


def test_rollup_span_histograms_merge_across_classes():
    r = RollupStore()
    r.record_span(1.0, MODEL, "interactive", "engine.decode", 0.4)
    r.record_span(2.0, MODEL, "batch", "engine.decode", 0.8)
    h = r.kind_hist(MODEL, "engine.decode", 0.0, 10.0)
    assert h.count == 2 and h.sum == pytest.approx(1.2)


# ---------------------------------------------------------------------------
# unit: burn math + alert lifecycle on hand-placed times
# ---------------------------------------------------------------------------

def _svc(**kw):
    kw.setdefault("burn_fast_window", (10.0, 60.0))
    kw.setdefault("burn_fast_factor", 10.0)
    kw.setdefault("burn_slow_window", (60.0, 300.0))
    kw.setdefault("burn_slow_factor", 1e9)   # keep slow out of the way
    kw.setdefault("burn_min_events", 2)
    return ServiceConfig(**kw)


class _FakeSpan:
    def __init__(self, name, start, end):
        self.name, self.start, self.end = name, start, end


class _FakeTrace:
    def __init__(self, trace_id, spans=(), shed=False):
        self.trace_id = trace_id
        self.spans = list(spans)

        class Root:
            attrs = {"shed": True} if shed else {}
        self.root = Root()


def test_burn_rate_is_miss_fraction_over_budget():
    ts = TelemetryStore(_svc())
    for i in range(8):                       # 2 misses in 8 → 25 %
        ts.observe(MODEL, "interactive", None, slo_miss=(i < 2),
                   error=False, t=float(i))
    # interactive objective 0.99 → budget 1 % → burn = 25
    assert ts.burn_rate(MODEL, "interactive", 10.0, 8.0) == \
        pytest.approx(25.0)
    # batch objective 0.95 → budget 5 %: same misses burn 5× less
    for i in range(8):
        ts.observe(MODEL, "batch", None, slo_miss=(i < 2),
                   error=False, t=float(i))
    assert ts.burn_rate(MODEL, "batch", 10.0, 8.0) == pytest.approx(5.0)


def test_burn_rate_zero_below_min_events():
    ts = TelemetryStore(_svc(burn_min_events=8))
    for i in range(4):
        ts.observe(MODEL, "interactive", None, slo_miss=True,
                   error=False, t=float(i))
    assert ts.burn_rate(MODEL, "interactive", 10.0, 4.0) == 0.0


def test_alert_lifecycle_exact_transition_times():
    ts = TelemetryStore(_svc())
    # a long healthy history, then a burst of misses
    for t in range(0, 60):
        ts.observe(MODEL, "interactive", None, slo_miss=False,
                   error=False, t=float(t))
    for t in (62, 64, 66, 68):
        ts.observe(MODEL, "interactive", None, slo_miss=True,
                   error=False, t=float(t))
    # t=70: short window all-miss (burn 100 ≥ 10), long window still
    # mostly healthy (4/54 ≈ 7.4 < 10) → pending, not firing
    ts.fold(MODEL, 70.0)
    a = ts._alerts[(MODEL, "interactive", "fast")]
    assert a.state == "pending" and a.pending_at == 70.0
    assert a.fired_at is None
    # more misses push the long window over the factor → fires at t=80
    for t in (72, 74, 76, 78):
        ts.observe(MODEL, "interactive", None, slo_miss=True,
                   error=False, t=float(t))
    ts.fold(MODEL, 80.0)
    assert a.state == "firing" and a.fired_at == 80.0
    # recovery: good traffic drains the SHORT window → resolves at t=100
    for t in range(82, 100):
        ts.observe(MODEL, "interactive", None, slo_miss=False,
                   error=False, t=float(t))
    ts.fold(MODEL, 100.0)
    assert a.state == "resolved" and a.resolved_at == 100.0
    assert (MODEL, "interactive", "fast") not in ts._alerts
    assert [(e["from"], e["to"], e["t"]) for e in ts.alert_log] == \
        [("pending", "pending", 70.0), ("pending", "firing", 80.0),
         ("firing", "resolved", 100.0)]


def test_pending_resolves_silently_if_short_window_recovers_first():
    ts = TelemetryStore(_svc())
    for t in range(0, 60):
        ts.observe(MODEL, "interactive", None, slo_miss=False,
                   error=False, t=float(t))
    for t in (62, 64):
        ts.observe(MODEL, "interactive", None, slo_miss=True,
                   error=False, t=float(t))
    ts.fold(MODEL, 66.0)
    assert ts._alerts[(MODEL, "interactive", "fast")].state == "pending"
    for t in range(67, 80):
        ts.observe(MODEL, "interactive", None, slo_miss=False,
                   error=False, t=float(t))
    ts.fold(MODEL, 80.0)                     # short window recovered
    assert (MODEL, "interactive", "fast") not in ts._alerts
    assert [e["to"] for e in ts.alert_log] == ["pending", "resolved"]
    # the never-fired alert is still listed as resolved history
    rows = ts.alerts(model=MODEL, state="resolved")
    assert len(rows) == 1 and rows[0]["fired_at"] is None


def test_firing_alert_blames_dominant_span_kind_and_carries_exemplars():
    ts = TelemetryStore(_svc())
    for i in range(12):
        spans = [_FakeSpan("engine.decode", 0.0, 5.0),
                 _FakeSpan("engine.prefill", 0.0, 0.2)]
        ts.observe(MODEL, "interactive", _FakeTrace(f"trace-{i}", spans),
                   slo_miss=True, error=False, t=float(i * 2))
    ts.fold(MODEL, 25.0)
    a = ts._alerts[(MODEL, "interactive", "fast")]
    assert a.state == "firing"
    assert a.burning_kind == "engine.decode"
    assert a.pool == "decode"                # KIND_POOLS mapping
    assert a.exemplars and a.exemplars[-1] == "trace-11"
    assert ts.burning_pool(MODEL) == "decode"
    assert set(a.exemplars) <= {f"trace-{i}" for i in range(12)}


def test_shed_requests_do_not_feed_the_alert_that_shed_them():
    ts = TelemetryStore(_svc())
    ts.observe(MODEL, "batch", _FakeTrace("trace-1", shed=True),
               slo_miss=True, error=False, t=1.0)
    assert ts.observed_total == 0
    _good, total, _shed = ts.rollups.counts(MODEL, "batch", 0.0, 5.0)
    assert total == 0
    ts.note_shed(MODEL, "batch", 2.0)
    assert ts.shed_total[MODEL] == 1
    _good, total, shed = ts.rollups.counts(MODEL, "batch", 0.0, 5.0)
    assert total == 0 and shed == 1          # shed ≠ served-badly


def test_fold_reports_the_registry_series():
    ts = TelemetryStore(_svc())
    out = ts.fold(MODEL, 10.0)
    expected = {"slo_burn_fast", "slo_burn_slow", "slo_burn_firing",
                "slo_shed_total"}
    expected |= {f"slo_burn_fast_{c}" for c in SLO_CLASSES}
    expected |= {f"slo_burn_slow_{c}" for c in SLO_CLASSES}
    expected |= {f"slo_attainment_{c}" for c in SLO_CLASSES}
    assert set(out) == expected
    assert all(k in KNOWN_METRICS for k in out)
    assert out["slo_attainment_interactive"] == 1.0   # no data = no misses
    # the aggregate burn series is the worst class AND-ed across windows
    for i in range(20):
        ts.observe(MODEL, "standard", None, slo_miss=True, error=False,
                   t=float(i))
    out = ts.fold(MODEL, 20.0)
    assert out["slo_burn_fast"] == out["slo_burn_fast_standard"] > 0
    assert out["slo_attainment_standard"] == 0.0


# ---------------------------------------------------------------------------
# unit: shedding policy
# ---------------------------------------------------------------------------

def _firing(cls, fired_at=100.0):
    return BurnAlert(model=MODEL, slo_class=cls, severity="fast",
                     state="firing", pending_at=fired_at,
                     fired_at=fired_at, short_burn=50.0, factor=10.0,
                     windows=(10.0, 60.0))


def test_shed_ladder_batch_first_then_standard_never_interactive():
    ts = TelemetryStore(_svc(shed_escalate_after=60.0))
    ts._alerts[(MODEL, "interactive", "fast")] = _firing("interactive")
    # right after firing: only batch is shed
    assert ts.should_shed(MODEL, "batch", 110.0) is not None
    assert ts.should_shed(MODEL, "standard", 110.0) is None
    assert ts.should_shed(MODEL, "interactive", 110.0) is None
    # one escalation period later: standard joins the shed set
    assert ts.should_shed(MODEL, "standard", 170.0) is not None
    # interactive is never shed, no matter how long the burn lasts
    assert ts.should_shed(MODEL, "interactive", 1e6) is None


def test_standard_burn_sheds_batch_only():
    ts = TelemetryStore(_svc())
    ts._alerts[(MODEL, "standard", "fast")] = _firing("standard")
    assert ts.should_shed(MODEL, "batch", 1e6) is not None
    # standard is the burning (protected) class — never shed for itself
    assert ts.should_shed(MODEL, "standard", 1e6) is None


def test_batch_only_burn_sheds_nothing():
    ts = TelemetryStore(_svc())
    ts._alerts[(MODEL, "batch", "fast")] = _firing("batch")
    for cls in SLO_CLASSES:
        assert ts.should_shed(MODEL, cls, 200.0) is None


def test_shed_retry_after_is_projected_recovery():
    ts = TelemetryStore(_svc())
    a = _firing("interactive")
    ts._alerts[(MODEL, "interactive", "fast")] = a
    retry = ts.should_shed(MODEL, "batch", 110.0)
    # short window 10 s, burn 50 vs factor 10 → 10 * (1 - 10/50) = 8 s
    assert retry == pytest.approx(10.0 * (1.0 - 10.0 / 50.0))
    assert retry == pytest.approx(ts.projected_recovery(a, 110.0))


def test_no_shed_when_nothing_fires():
    ts = TelemetryStore(_svc())
    assert ts.should_shed(MODEL, "batch", 100.0) is None


# ---------------------------------------------------------------------------
# integration: real planes
# ---------------------------------------------------------------------------

#: sub-nanosecond targets: every served request is an SLO miss, so burn
#: alerts fire as soon as the windows fill
_MISS_TARGETS = {"interactive": SLOTarget(ttft=1e-9, e2el=1e-9),
                 "standard": SLOTarget(ttft=10.0, e2el=300.0),
                 "batch": SLOTarget(ttft=60.0, e2el=1800.0)}


def plane(services=None, **cluster_kw):
    cp = ControlPlane(ClusterSpec(num_nodes=4,
                                  services=services or ServiceConfig(),
                                  **cluster_kw),
                      alert_rules=[])
    cp.add_tenant("t", "sk-test")
    cp.register_model(configs.get(MODEL))
    return cp


def unified_plane(services=None):
    cp = plane(services=services)
    AdminClient(cp).apply(ModelDeploymentSpec(
        model=MODEL, replicas=1, max_replicas=2, est_load_time=5.0))
    cp.run_until(120.0)
    return cp


def burn_services(**kw):
    kw.setdefault("slo_targets", dict(_MISS_TARGETS))
    kw.setdefault("burn_fast_window", (15.0, 45.0))
    kw.setdefault("burn_min_events", 4)
    return ServiceConfig(**kw)


def complete_one(cp, slo_class="interactive", prompt_len=64, out=4):
    client = ServingClient(cp, api_key="sk-test")
    pending = client.completions(model=MODEL,
                                 prompt=list(range(1, prompt_len + 1)),
                                 max_tokens=out, target_output_len=out,
                                 slo_class=slo_class)
    resp = pending.result(max_wait=600.0)
    assert resp.choices[0].finish_reason == "length"
    return pending.request


def drive_waves(cp, waves=10, slo_class="interactive"):
    """Bursts of 3 concurrent requests every 6 s: dense enough that the
    15 s fast window always holds >= burn_min_events observations, with
    the 5 s scrape evaluating between waves.  No idle tail — the short
    window draining is exactly what RESOLVES a burn alert."""
    client = ServingClient(cp, api_key="sk-test")
    for _ in range(waves):
        pendings = [client.completions(model=MODEL,
                                       prompt=list(range(1, 65)),
                                       max_tokens=4, target_output_len=4,
                                       slo_class=slo_class)
                    for _ in range(3)]
        for p in pendings:
            p.result(max_wait=600.0)
        cp.run_until(cp.loop.now + 6.0)


def drive_until_firing(cp, waves=10, slo_class="interactive"):
    drive_waves(cp, waves=waves, slo_class=slo_class)
    return [a for a in cp.telemetry.alerts(model=MODEL)
            if a["state"] == "firing"]


def test_plane_wires_telemetry_and_scrape_emits_burn_series():
    cp = unified_plane(services=burn_services())
    assert cp.telemetry is not None
    assert cp.tracer.telemetry is cp.telemetry
    firing = drive_until_firing(cp)
    assert firing, "all-miss traffic must fire a burn alert"
    fast = [a for a in firing if a["severity"] == "fast"]
    assert fast and fast[0]["slo_class"] == "interactive"
    assert fast[0]["exemplars"], "firing alert carries exemplar traces"
    # every exemplar is a retained trace id the admin can look up
    admin = AdminClient(cp)
    assert all(admin.trace(tid) is not None
               for tid in fast[0]["exemplars"])
    mg = cp.metrics_gateway
    cfg_id = next(iter(mg.history))
    series = mg.series(cfg_id, "slo_burn_fast", 0.0)
    assert series and series[-1][1] > 1.0
    att = mg.series(cfg_id, "slo_attainment_interactive", 0.0)
    assert att and att[-1][1] == 0.0


def test_admin_alert_verbs_and_watch():
    cp = unified_plane(services=burn_services())
    admin = AdminClient(cp)
    watch = admin.watch_alerts()
    got = []
    watch.subscribe(got.append)
    drive_until_firing(cp)
    rows = admin.alerts(model=MODEL)
    assert rows and all(r["model"] == MODEL for r in rows)
    assert admin.alerts(model="nope") == []
    assert admin.alerts(state="firing")
    assert admin.alerts(slo_class="interactive")
    # the watch saw every lifecycle transition, in order
    assert [a["state"] for a in watch.alerts][:2] == ["pending", "firing"]
    assert got == watch.alerts
    n = len(watch.alerts)
    watch.stop()
    cp.run_until(cp.loop.now + 60.0)
    assert len(watch.alerts) == n            # unsubscribed on stop


def test_admin_without_telemetry_raises():
    cp = unified_plane()
    admin = AdminClient(cp.reconciler)       # bare reconciler
    with pytest.raises(TypeError):
        admin.alerts()
    with pytest.raises(TypeError):
        admin.watch_alerts()


def test_telemetry_disabled_or_tracing_disabled_plane_has_none():
    cp = unified_plane(services=ServiceConfig(telemetry_enabled=False))
    assert cp.telemetry is None
    complete_one(cp)                          # serves fine without it
    cp = unified_plane(services=ServiceConfig(tracing_enabled=False))
    assert cp.telemetry is None               # no tracer feed → no store


def test_gateway_sheds_batch_with_retry_after_while_fast_burn_fires():
    cp = unified_plane(services=burn_services(slo_shed_enabled=True))
    drive_until_firing(cp)
    client = ServingClient(cp, api_key="sk-test")
    with pytest.raises(APIStatusError) as ei:
        client.completions(model=MODEL, prompt=[1, 2, 3], max_tokens=2,
                           target_output_len=2, slo_class="batch")
    assert ei.value.status == 461
    assert ei.value.error.retry_after is not None
    assert ei.value.error.retry_after >= 1.0
    assert "Shedding" in ei.value.error.message
    assert cp.web_gateway.stats.rejected_shed == 1
    assert cp.telemetry.shed_total[MODEL] == 1
    # interactive (the protected class) is still admitted
    complete_one(cp, slo_class="interactive")
    # shedding off (the default): batch is admitted even while firing
    cp2 = unified_plane(services=burn_services())
    drive_until_firing(cp2)
    complete_one(cp2, slo_class="batch")
    assert cp2.web_gateway.stats.rejected_shed == 0


def test_alert_rule_metric_keys_validated_422():
    cp = plane()
    admin = AdminClient(cp)
    rule = {"name": "r", "metric": "slo_burn_fst", "op": "gt",
            "threshold": 1.0, "for_duration": 20.0, "delta": 1}
    with pytest.raises(APIStatusError) as ei:
        admin.apply(model=MODEL, replicas=1, alert_rules=[rule])
    assert ei.value.status == 422
    assert ei.value.error.param == "alert_rules[0].metric"
    assert "slo_burn_fast" in ei.value.error.message   # suggestion
    # span-family typos get the span-kind spelling hint
    with pytest.raises(APIStatusError) as ei:
        admin.apply(model=MODEL, replicas=1, alert_rules=[
            dict(rule, metric="span_engine.deocde_p99_ms")])
    assert "span_<kind>" in ei.value.error.message
    # a registry-valid metric and the "burning" pool sentinel both pass
    dep = admin.apply(model=MODEL, replicas=1, alert_rules=[
        dict(rule, metric="slo_burn_fast", pool="burning")])
    assert dep.spec.alert_rules[0]["pool"] == "burning"
    with pytest.raises(APIStatusError) as ei:
        admin.apply(model=MODEL, replicas=1, alert_rules=[
            dict(rule, metric="slo_burn_fast", pool="gpu")])
    assert ei.value.error.param == "alert_rules[0].pool"


def test_burning_pool_hint_resolves_only_for_disagg_deployments():
    cp = unified_plane(services=burn_services())
    drive_until_firing(cp)
    # telemetry blames a concrete span kind, but a unified deployment
    # has no pools — the autoscaler hint must fall back to None
    # (plain replica scaling), never a pool patch the reconciler
    # would reject
    cfg_id = next(iter(cp.metrics_gateway.history))
    assert cp.telemetry.burning_pool(MODEL) in (None, "prefill", "decode")
    assert cp.autoscaler.pool_hint(cfg_id) is None


# ---------------------------------------------------------------------------
# harness: shed vs missed split
# ---------------------------------------------------------------------------

def test_harness_reports_shed_separately_from_missed():
    from benchmarks.harness import ClientRecord, ClientRecorder
    rec = ClientRecorder()
    # two served interactive requests: one meets, one misses
    ok = rec._record(1, 0.0, "interactive")
    ok.t_first, ok.t_last, ok.n_tokens = 0.5, 1.0, 2
    late = rec._record(2, 0.0, "interactive")
    late.t_first, late.t_last, late.n_tokens = 50.0, 100.0, 2
    # one shed at submit, one accepted-then-expired (also 461)
    rec.reject("rej-1", 0.0, 461, "interactive")
    expired = rec._record(3, 0.0, "interactive")
    expired.error_status = 461               # stream error, NOT rejected
    out = rec.slo_attainment()
    # shed excluded from the denominator; the expiry still counts missed
    assert out["slo_attainment_interactive"] == pytest.approx(1 / 3)
    assert out["slo_shed_interactive"] == pytest.approx(1 / 4)
    assert rec.summary()["shed"] == 1
    assert ClientRecord(0.0, error_status=429, rejected=True).shed
    assert not ClientRecord(0.0, error_status=461).shed


# ---------------------------------------------------------------------------
# determinism: twin runs, schedule-identical telemetry
# ---------------------------------------------------------------------------

def test_slo_burn_twin_runs_bit_identical_including_alert_timeline():
    from benchmarks.slo_burn import run_burn_scenario
    a = run_burn_scenario("burn", 40, ramp_s=20.0, sanitize=True)
    b = run_burn_scenario("burn", 40, ramp_s=20.0, sanitize=True)
    assert a["trace_digest"] == b["trace_digest"]
    assert a["events_run"] == b["events_run"]
    assert a["span_forest_digest"] == b["span_forest_digest"]
    assert a["alert_digest"] == b["alert_digest"]
    assert a == b


def test_telemetry_on_off_is_schedule_identical():
    """The determinism guarantee: telemetry records synchronously inside
    `Tracer.finish` and evaluates inside the scrape — enabling it must
    not change WHAT runs on the EventLoop, only what is remembered
    about it."""
    def run(enabled: bool):
        cp = plane(services=burn_services(telemetry_enabled=enabled),
                   sanitize=True)
        AdminClient(cp).apply(ModelDeploymentSpec(
            model=MODEL, replicas=1, max_replicas=2, est_load_time=5.0))
        cp.run_until(120.0)
        drive_waves(cp, waves=6)
        return cp
    on = run(True)
    off = run(False)
    assert on.telemetry is not None and off.telemetry is None
    assert on.loop.trace_digest() == off.loop.trace_digest()
    assert on.loop.events_run == off.loop.events_run
    # and the enabled run did actually evaluate alert transitions — the
    # digest equality above is not vacuous
    assert on.telemetry.alert_log
