"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body runs in Python on CPU; on TPU pass interpret=False)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import flash_prefill_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


@pytest.mark.slow
@pytest.mark.parametrize("s,h,kv,d,bs,mb", [
    (4, 8, 2, 128, 16, 8),
    (2, 4, 4, 64, 32, 4),
    (3, 9, 3, 64, 16, 5),       # GQA ratio 3 (smollm-like)
    (1, 16, 1, 128, 32, 16),    # MQA (recurrentgemma-like)
    (5, 8, 8, 96, 16, 3),       # MHA, phi3-like head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(s, h, kv, d, bs, mb, dtype, rng):
    nb = s * mb + 1
    q = jnp.asarray(rng.normal(size=(s, h, d)), dtype)
    pk = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), dtype)
    pv = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), dtype)
    bt = jnp.asarray(rng.integers(0, nb, size=(s, mb)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, mb * bs + 1, size=(s,)), jnp.int32)
    ref = paged_attention_ref(q, pk, pv, bt, lens)
    pal = paged_attention(q, pk, pv, bt, lens, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_single_token_context(rng):
    """ctx=1 edge: only the freshly-written slot participates."""
    s, h, kv, d, bs, mb = 2, 4, 2, 64, 16, 4
    nb = 16
    q = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(nb, bs, kv, d)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, nb, size=(s, mb)), jnp.int32)
    lens = jnp.ones((s,), jnp.int32)
    ref = paged_attention_ref(q, pk, pv, bt, lens)
    pal = paged_attention(q, pk, pv, bt, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # with ctx=1, output must equal v at the first slot (softmax of one)
    v0 = np.asarray(pv)[np.asarray(bt)[:, 0], 0]          # (S, KV, D)
    v0 = np.repeat(v0, h // kv, axis=1)
    np.testing.assert_allclose(np.asarray(ref), v0, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("b,t,h,kv,d,window,bq,bk", [
    (2, 256, 4, 2, 64, 0, 64, 64),
    (1, 256, 8, 8, 128, 0, 128, 128),
    (2, 512, 4, 1, 64, 128, 64, 128),   # windowed (griffin-like)
    (1, 128, 9, 3, 64, 0, 32, 64),
    (1, 512, 2, 2, 128, 256, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(b, t, h, kv, d, window, bq, bk, dtype, rng):
    q = jnp.asarray(rng.normal(size=(b, t, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, t, kv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, t, kv, d)), dtype)
    ref = flash_prefill_ref(q, k, v, window)
    pal = flash_prefill(q, k, v, window=window, bq=bq, bk=bk, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(pal, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_prefill_is_causal(rng):
    """Perturbing future tokens must not change earlier outputs."""
    b, t, h, d = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, d)), jnp.float32)
    out1 = flash_prefill(q, k, v, bq=64, bk=64, interpret=True)
    k2 = k.at[:, t // 2:].add(5.0)
    v2 = v.at[:, t // 2:].add(5.0)
    out2 = flash_prefill(q, k2, v2, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :t // 2]),
                               np.asarray(out2[:, :t // 2]),
                               rtol=1e-6, atol=1e-6)
