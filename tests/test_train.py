"""Training substrate: optimizer math, schedules, trainer loop, exact
checkpoint-restart resume, data-pipeline determinism."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.tokens import DataConfig, TokenPipeline
from repro.train.loop import Trainer, TrainerConfig
from repro.train.optimizer import AdamW, cosine_schedule, global_norm, \
    wsd_schedule


def test_wsd_schedule_shape():
    lr = wsd_schedule(1e-3, warmup_steps=10, stable_steps=50, decay_steps=20)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3)
    assert float(lr(40)) == pytest.approx(1e-3)       # stable plateau
    assert float(lr(70)) < 2e-4                        # decayed
    assert float(lr(80)) == pytest.approx(1e-5, rel=0.1)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(5)) == pytest.approx(5e-4)
    assert float(lr(100)) == pytest.approx(1e-4, rel=0.01)


def test_adamw_clips_gradients():
    opt = AdamW(lambda s: 1e-3, clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    opt_state = opt.init(params)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = opt.update(grads, opt_state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    # effective update magnitude bounded by lr (clip + adam normalisation)


def test_adamw_decreases_quadratic():
    opt = AdamW(lambda s: 0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, st, _ = opt.update(grads, st, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b7a, b7b = p1.batch(7), p2.batch(7)
    np.testing.assert_array_equal(b7a["tokens"], b7b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b7a["tokens"][:, 1:], b7a["labels"][:, :-1])
    assert not np.array_equal(p1.batch(8)["tokens"], b7a["tokens"])


def test_trainer_learns_and_resumes_exactly(tmp_path):
    cfg = configs.get("smollm-135m").reduced()
    tc = TrainerConfig(seq_len=64, global_batch=4, steps=14, ckpt_every=6,
                       ckpt_dir=str(tmp_path), log_every=100)
    tr = Trainer(cfg, tc)
    hist = tr.run(steps=12)           # "crash" right after the step-12 ckpt
    assert hist[-1]["loss"] < hist[0]["loss"], "no learning signal"

    # restart -> resumes at 12 and continues to 14
    tr3 = Trainer(cfg, tc)
    assert tr3.step_idx == 12
    h3 = tr3.run()
    assert tr3.step_idx == 14
    assert np.isfinite(h3[-1]["loss"])

    # exact-resume: a run without interruption matches the resumed one
    tc3 = TrainerConfig(**{**tc.__dict__, "ckpt_dir": str(tmp_path) + "_b",
                           "ckpt_every": 1000})
    tr4 = Trainer(cfg, tc3)
    h4 = tr4.run()
    assert h4[-1]["loss"] == pytest.approx(h3[-1]["loss"], rel=1e-5), \
        "restart-from-checkpoint diverged from uninterrupted run"
