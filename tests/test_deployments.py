"""Declarative control plane: ModelDeploymentSpec validation and wire
round-trips, the reconciler's convergence semantics (scale-up pacing,
drain-before-scancel scale-down, rolling updates that never drop below
min_replicas ready, observed_generation lag, node-failure reconvergence),
the autoscaler-as-spec-patcher webhook path, the AdminClient verbs/watch
stream, the priority-ordered gateway queue, and the SlurmSubmit sbatch
coercion regression."""
import pytest

from repro import configs
from repro.api import AdminClient, APIStatusError, ServingClient
from repro.api.admin import DeploymentWatch
from repro.core.controller import ClusterSpec, ControlPlane
from repro.core.deployments import (COND_AVAILABLE, COND_PROGRESSING,
                                    COND_READY, Condition, DeploymentStatus,
                                    ModelDeploymentSpec)
from repro.core.router import GatewayQueue
from repro.core.slurm import JobState
from repro.engine.request import Request, SamplingParams

MODEL = "mistral-small-24b"


def mk_plane(**kw):
    spec = ClusterSpec(num_nodes=kw.pop("num_nodes", 4),
                       gpus_per_node=kw.pop("gpus_per_node", 2),
                       max_num_seqs=16, num_blocks=512, block_size=16,
                       max_model_len=2048, **kw)
    cp = ControlPlane(spec)
    cp.add_tenant("uni", "sk-test")
    cp.register_model(configs.get(MODEL))
    return cp


def mk_admin(**kw):
    cp = mk_plane(**kw)
    return cp, AdminClient(cp)


def req(n=16, out=4, priority=0):
    return Request(prompt_tokens=[1] * n, priority=priority,
                   sampling=SamplingParams(target_output_len=out,
                                           max_new_tokens=out))


# ---------------------------------------------------------------------------
# spec validation + wire round-trip
# ---------------------------------------------------------------------------

def test_spec_roundtrip():
    spec = ModelDeploymentSpec(model=MODEL, replicas=2, min_replicas=1,
                               max_replicas=4, routing_policy="least_loaded",
                               queue_capacity=16, queue_ttl=20.0,
                               priority_class=3, gpus_per_node=2,
                               est_load_time=30.0, drain_grace=45.0)
    spec.validate()
    assert ModelDeploymentSpec.from_dict(spec.to_dict()) == spec


@pytest.mark.parametrize("field,value", [
    ("model", ""), ("model", 7), ("model_version", ""),
    ("replicas", "2"), ("replicas", -1), ("min_replicas", -1),
    ("max_replicas", 0), ("routing_policy", "weighted_random"),
    ("queue_capacity", -1), ("queue_ttl", 0.0), ("priority_class", 1.5),
    ("gpus_per_node", 0), ("nodes", 0), ("partition", ""),
    ("est_load_time", -1.0), ("max_model_len", 0), ("drain_grace", -1.0),
])
def test_spec_validation_is_field_addressed(field, value):
    spec = ModelDeploymentSpec(model=MODEL)
    setattr(spec, field, value)
    with pytest.raises(APIStatusError) as ei:
        spec.validate()
    assert ei.value.status == 422
    assert ei.value.error.param == field


def test_spec_replicas_must_lie_in_window():
    with pytest.raises(APIStatusError) as ei:
        ModelDeploymentSpec(model=MODEL, replicas=9, max_replicas=4).validate()
    assert ei.value.error.param == "replicas"
    with pytest.raises(APIStatusError) as ei:
        ModelDeploymentSpec(model=MODEL, min_replicas=5,
                            max_replicas=2).validate()
    assert ei.value.error.param == "max_replicas"


def test_apply_unknown_model_rejected():
    cp, admin = mk_admin()
    with pytest.raises(APIStatusError) as ei:
        admin.apply(model="never-registered")
    assert ei.value.error.param == "model"


def test_condition_and_status_roundtrip():
    st = DeploymentStatus()
    assert st.set_condition(COND_READY, True, "AllReplicasReady", "2/2", 5.0)
    assert not st.set_condition(COND_READY, True, "AllReplicasReady",
                                "2/2 again", 9.0)   # no flip
    cond = st.condition(COND_READY)
    assert cond.last_transition_time == 5.0 and cond.message == "2/2 again"
    assert Condition.from_dict(cond.to_dict()) == cond


# ---------------------------------------------------------------------------
# reconciler convergence
# ---------------------------------------------------------------------------

def test_apply_converges_and_observed_generation_lags():
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=2, max_replicas=4,
                      est_load_time=10.0)
    assert dep.generation == 1 and dep.status.observed_generation == 0
    cp.run_until(12.0)   # submitted but still loading
    assert dep.status.observed_generation == 0
    assert not dep.status.condition(COND_READY).status
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    assert dep.status.observed_generation == 1
    assert dep.status.ready_replicas == 2
    # spec change: generation moves immediately, observed lags again
    admin.scale(MODEL, 3)
    assert dep.generation == 2 and dep.status.observed_generation == 1
    cp.run_until(cp.loop.now + 6.0)
    assert dep.status.observed_generation == 1       # not converged yet
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    assert dep.status.observed_generation == 2
    cp.db.check_invariants()


def test_apply_identical_spec_is_noop():
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=1, est_load_time=10.0)
    g = dep.generation
    assert admin.apply(model=MODEL, replicas=1, est_load_time=10.0) is dep
    assert dep.generation == g


def test_scale_outside_window_rejected():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, min_replicas=1, max_replicas=2,
                est_load_time=5.0)
    with pytest.raises(APIStatusError) as ei:
        admin.scale(MODEL, 5)
    assert ei.value.error.param == "replicas"


def test_scale_down_drains_in_flight_before_scancel():
    cp, admin = mk_admin(num_nodes=4, gpus_per_node=1)
    dep = admin.apply(model=MODEL, replicas=2, max_replicas=4,
                      est_load_time=10.0, drain_grace=300.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    gw = cp.web_gateway
    # long-running requests on both instances
    reqs = [req(n=64, out=400) for _ in range(6)]
    for r in reqs:
        assert gw.handle("sk-test", MODEL, r) == 200
    cp.run_until(cp.loop.now + 2.0)
    busy = [i for i in cp.registry.values() if i.engine.has_work()]
    assert len(busy) == 2
    admin.scale(MODEL, 1)
    # next reconcile tick starts the drain: the victim keeps serving
    cp.run_until(cp.loop.now + 6.0)
    draining = [i for i in cp.registry.values() if i.draining]
    assert len(draining) == 1
    victim = draining[0]
    assert victim.alive and victim.engine.has_work()
    assert dep.status.draining_replicas == 1
    # new requests are routed around the draining instance
    before = victim.engine.metrics.requests_finished + \
        len(victim.engine.scheduler.running) + \
        len(victim.engine.scheduler.waiting)
    r_new = req(out=2)
    assert gw.handle("sk-test", MODEL, r_new) == 200
    cp.run_until(cp.loop.now + 1.0)
    after = victim.engine.metrics.requests_finished + \
        len(victim.engine.scheduler.running) + \
        len(victim.engine.scheduler.waiting)
    assert after == before
    # drain completes: every stream finishes, nothing failed, then scancel
    cp.run_until(cp.loop.now + 400.0)
    assert all(r.status.value == "finished" for r in reqs)
    assert not victim.alive                      # scancel'd after idle
    assert len(cp.ready_endpoints(MODEL)) == 1
    assert dep.status.ready_replicas == 1 and not dep.status.draining_replicas
    cp.db.check_invariants()


def test_scale_down_grace_deadline_forces_cancel():
    cp, admin = mk_admin(num_nodes=4, gpus_per_node=1)
    admin.apply(model=MODEL, replicas=2, max_replicas=4,
                est_load_time=10.0, drain_grace=8.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    reqs = [req(n=64, out=100_000) for _ in range(4)]   # never finishes
    gw = cp.web_gateway
    for r in reqs:
        assert gw.handle("sk-test", MODEL, r) == 200
    cp.run_until(cp.loop.now + 2.0)
    admin.scale(MODEL, 1)
    cp.run_until(cp.loop.now + 60.0)
    # grace expired -> force scancel; the in-flight work on the victim
    # failed, but the deployment converged to 1 replica
    assert len(cp.ready_endpoints(MODEL)) == 1
    assert sum(1 for i in cp.registry.values() if i.alive) == 1
    cp.db.check_invariants()


def test_rolling_update_never_drops_below_min_replicas_ready():
    cp, admin = mk_admin(num_nodes=6, gpus_per_node=1)
    dep = admin.apply(model=MODEL, replicas=3, min_replicas=2,
                      max_replicas=4, est_load_time=10.0)
    assert admin.wait(MODEL, "Ready", timeout=200.0)
    assert dep.status.ready_replicas == 3
    old_jobs = set(dep._job_template)
    ready_floor = []
    cp.loop.every(1.0, lambda now: ready_floor.append(
        dep.status.ready_replicas))
    # bump the template (new model version -> staged replace with drain)
    admin.apply(model=MODEL, model_version="2", replicas=3, min_replicas=2,
                max_replicas=4, est_load_time=10.0)
    assert dep.template_generation == 2
    assert admin.wait(MODEL, "Ready", timeout=600.0)
    cp.run_until(cp.loop.now + 30.0)
    # converged on 3 replicas, ALL on the new template, none of the old jobs
    assert dep.status.ready_replicas == 3
    assert set(dep._job_template) & old_jobs == set()
    assert all(g == 2 for g in dep._job_template.values())
    assert dep.status.observed_generation == dep.generation
    # the rolling invariant: ready (serving) replicas never below min
    assert min(ready_floor) >= 2
    # and the version actually rolled out on the wire
    assert all(ep["model_version"] == "2"
               for ep in cp.ready_endpoints(MODEL))
    cp.db.check_invariants()


def test_node_failure_restores_spec_with_condition_trail():
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=2, max_replicas=4,
                      est_load_time=10.0)
    assert admin.wait(MODEL, "Ready", timeout=150.0)
    t_kill = cp.loop.now
    victim = cp.ready_endpoints(MODEL)[0]["node"]
    cp.slurm.fail_node(victim)
    cp.run_until(cp.loop.now + 10.0)
    cond = dep.status.condition(COND_READY)
    assert not cond.status and cond.reason == "ReplicaFailure"
    assert admin.wait(MODEL, "Ready", timeout=200.0)
    assert dep.status.ready_replicas == 2
    flips = [(c, s, r) for t, c, s, r in dep.transitions if t >= t_kill]
    assert (COND_READY, False, "ReplicaFailure") in flips
    assert (COND_READY, True, "AllReplicasReady") in flips
    cp.db.check_invariants()


def test_delete_tears_everything_down():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=2, max_replicas=4, est_load_time=5.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    assert admin.delete(MODEL)
    assert not admin.delete(MODEL)            # second delete: gone
    cp.run_until(cp.loop.now + 30.0)
    assert admin.get(MODEL) is None
    assert cp.db["ai_model_configurations"].rows == {}
    assert cp.db["ai_model_endpoint_jobs"].rows == {}
    assert not any(i.alive for i in cp.instances_spawned)
    cp.db.check_invariants()


# ---------------------------------------------------------------------------
# autoscaler as spec patcher
# ---------------------------------------------------------------------------

def test_webhook_patches_spec_clamped_to_window():
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=1, min_replicas=1,
                      max_replicas=2, est_load_time=5.0)
    gw = cp.metrics_gateway
    for _ in range(4):
        assert gw.grafana_webhook({"config_id": dep.config_id,
                                   "delta": +1, "rule": "qt"}) == 200
    assert dep.spec.replicas == 2            # clamped to max_replicas
    assert len(gw.scale_events) == 1         # clamped no-ops are not events
    # the DB row is actuation state: reconciler syncs it to the spec
    cp.run_until(cp.loop.now + 10.0)
    assert cp.db["ai_model_configurations"].get(
        dep.config_id)["instances"] == 2
    for _ in range(4):
        gw.grafana_webhook({"config_id": dep.config_id,
                            "delta": -1, "rule": "idle"})
    assert dep.spec.replicas == 1            # clamped to min_replicas
    assert len(gw.scale_events) == 2


def test_webhook_legacy_path_for_unmanaged_configs():
    cp, admin = mk_admin()
    row = cp.add_model(configs.get(MODEL), instances=1, est_load_time=5.0)
    assert cp.metrics_gateway.grafana_webhook(
        {"config_id": row["id"], "delta": +1, "rule": "qt"}) == 200
    assert cp.db["ai_model_configurations"].get(row["id"])["instances"] == 2


def test_legacy_job_worker_skips_managed_configs():
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=1, est_load_time=5.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    # a direct DB mutation on a MANAGED config is reverted by the
    # reconciler (the spec is the source of truth), not amplified by the
    # legacy Job Worker loop
    cp.db["ai_model_configurations"].update(dep.config_id, instances=4)
    cp.run_until(cp.loop.now + 60.0)
    assert cp.db["ai_model_configurations"].get(
        dep.config_id)["instances"] == 1
    assert len(cp.ready_endpoints(MODEL)) == 1


# ---------------------------------------------------------------------------
# AdminClient verbs + watch stream
# ---------------------------------------------------------------------------

def test_admin_verbs_and_watch_events():
    cp, admin = mk_admin()
    watch = admin.watch()
    dep = admin.apply(model=MODEL, replicas=1, max_replicas=3,
                      est_load_time=5.0)
    assert admin.get(MODEL) is dep
    assert admin.list() == [dep]
    assert admin.status(MODEL)["spec"]["model"] == MODEL
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    admin.scale(MODEL, 2)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    admin.delete(MODEL)
    types = [e.type for e in watch.events]
    assert types[0] == "ADDED"
    assert "SCALED" in types and "CONDITION" in types
    assert types[-1] == "DELETED"
    # events carry full to_dict snapshots (the wire view)
    assert watch.events[0].object["spec"]["replicas"] == 1
    seen = []
    watch.subscribe(seen.append)
    watch.stop()
    assert watch.closed
    # a stopped watch is unsubscribed: further verbs deliver nothing
    admin.apply(model=MODEL, replicas=1, est_load_time=5.0)
    assert seen == []


def test_rollback_restores_previous_spec_revision():
    """kubectl rollout undo analogue: every applied spec change keeps the
    outgoing revision; `rollback` re-applies it (template changes roll
    back through the same surge/drain machinery), and a second rollback
    returns to where you started."""
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, max_replicas=3, model_version="1",
                est_load_time=5.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    # template change: v1 -> v2 rolls the replica
    admin.apply(model=MODEL, replicas=1, max_replicas=3, model_version="2",
                est_load_time=5.0)
    assert admin.wait(MODEL, "Ready", timeout=240.0)
    cp.run_until(cp.loop.now + 30.0)     # let the worker reap dead rows
    dep = admin.get(MODEL)
    assert dep.spec.model_version == "2"
    assert dep.template_generation == 2
    eps = cp.ready_endpoints(MODEL)
    assert eps and all(e["model_version"] == "2" for e in eps)

    gen0 = dep.generation
    admin.rollback(MODEL)
    assert dep.spec.model_version == "1"
    assert dep.generation == gen0 + 1
    assert dep.template_generation == 3          # rolls forward, not back
    assert admin.wait(MODEL, "Ready", timeout=240.0)
    cp.run_until(cp.loop.now + 30.0)
    eps = cp.ready_endpoints(MODEL)
    assert eps and all(e["model_version"] == "1" for e in eps)

    # undo the undo: back on v2
    admin.rollback(MODEL)
    assert dep.spec.model_version == "2"
    assert admin.wait(MODEL, "Ready", timeout=240.0)


def test_rollback_without_history_is_422():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, est_load_time=5.0)
    with pytest.raises(APIStatusError) as ei:
        admin.rollback(MODEL)
    assert ei.value.status == 422 and ei.value.error.param == "name"
    with pytest.raises(APIStatusError):
        admin.rollback("no-such-deployment")


def test_rollback_revisions_are_snapshots_not_references():
    """Autoscaler patches mutate dep.spec in place; the revision history
    must hold copies, or a rollback would 'restore' the mutated state."""
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=1, max_replicas=4,
                      est_load_time=5.0)
    admin.apply(model=MODEL, replicas=1, max_replicas=4, est_load_time=5.0,
                queue_capacity=8)
    # autoscaler-style in-place patch on the live spec
    cp.reconciler.patch_replicas(dep.config_id, +2)
    assert dep.spec.replicas == 3
    assert dep.revisions[-1].replicas == 1       # snapshot untouched
    admin.rollback(MODEL)
    assert dep.spec.queue_capacity is None
    assert dep.spec.replicas == 1


def test_rollback_skips_revisions_identical_to_drifted_spec():
    """In-place autoscaler drift can make the newest snapshot equal the
    live spec; rollback must not 'restore' it (a silent no-op that
    destroys the revision) — it skips to the newest distinct one, or
    422s with history intact when none differs."""
    cp, admin = mk_admin()
    dep = admin.apply(model=MODEL, replicas=1, max_replicas=4,
                      est_load_time=5.0)
    admin.apply(model=MODEL, replicas=3, max_replicas=4, est_load_time=5.0)
    # drift the live spec back to the snapshot's state (no revision push)
    cp.reconciler.patch_replicas(dep.config_id, -2)
    assert dep.spec.replicas == 1 and dep.revisions[-1] == dep.spec
    with pytest.raises(APIStatusError) as ei:
        admin.rollback(MODEL)
    assert "differing" in ei.value.error.message
    assert len(dep.revisions) == 1           # history NOT destroyed
    # with an older distinct revision, rollback lands there instead
    admin.apply(model=MODEL, replicas=3, max_replicas=4, est_load_time=5.0,
                queue_capacity=9)
    cp.reconciler.patch_replicas(dep.config_id, -2)
    admin.rollback(MODEL)
    assert dep.spec.queue_capacity is None and dep.spec.replicas == 1


def test_rollback_history_is_bounded():
    from repro.core.deployments import MAX_REVISIONS
    cp, admin = mk_admin()
    for i in range(MAX_REVISIONS + 5):
        admin.apply(model=MODEL, replicas=1, max_replicas=4,
                    est_load_time=5.0, queue_capacity=i + 1)
    dep = admin.get(MODEL)
    assert len(dep.revisions) == MAX_REVISIONS


def test_watch_is_a_stream_session():
    # the watch reuses the TokenStream subscription machinery
    from repro.api.streaming import StreamSession
    assert issubclass(DeploymentWatch, StreamSession)
    w = DeploymentWatch()
    done = []
    w.on_done(done.append)
    w.stop()
    assert done == [w]


def test_apply_spec_object_and_dict_forms():
    cp, admin = mk_admin()
    dep = admin.apply(ModelDeploymentSpec(model=MODEL, replicas=1,
                                          est_load_time=5.0))
    assert dep.spec.est_load_time == 5.0
    dep2 = admin.apply({"model": MODEL, "replicas": 1,
                        "est_load_time": 5.0})
    assert dep2 is dep                       # same deployment, no-op
    with pytest.raises(TypeError):
        admin.apply(ModelDeploymentSpec(model=MODEL), replicas=2)


# ---------------------------------------------------------------------------
# per-deployment gateway policy
# ---------------------------------------------------------------------------

def test_per_deployment_routing_policy_override():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=2, max_replicas=4,
                routing_policy="session_affinity", est_load_time=5.0)
    assert admin.wait(MODEL, "Ready", timeout=120.0)
    gw = cp.web_gateway
    assert gw.router_for(MODEL).name == "session_affinity"
    assert gw.router_for("other-model") is gw.router
    # every turn of one session lands on the same instance
    for _ in range(6):
        r = req(out=2)
        r.session_id = "chat-1"
        assert gw.handle("sk-test", MODEL, r) == 200
    cp.run_until(cp.loop.now + 30.0)
    loads = sorted(i.engine.metrics.requests_finished
                   for i in cp.registry.values())
    assert loads == [0, 6]
    assert "per_model" in gw.router_stats()


# ---------------------------------------------------------------------------
# priority-ordered gateway queue (+ aging) — ROADMAP follow-up
# ---------------------------------------------------------------------------

def test_queue_dequeues_by_priority_fifo_within_class():
    q = GatewayQueue(capacity=8, ttl=60.0)
    sent = []
    disp = lambda r: (sent.append(r.priority), 200)[1]
    for pri in (0, 5, 0, 5, 2):
        q.offer(req(priority=pri), MODEL, 0.0, dispatch=disp)
    q.drain(MODEL, 1.0, can_dispatch=lambda m: True)
    assert sent == [5, 5, 2, 0, 0]


def test_queue_fifo_preserved_for_equal_priorities():
    q = GatewayQueue(capacity=8, ttl=60.0)
    sent = []
    for i in range(4):
        r = req()
        r.tag = i
        q.offer(r, MODEL, float(i), dispatch=lambda rr: (sent.append(rr.tag),
                                                         200)[1])
    q.drain(MODEL, 5.0, can_dispatch=lambda m: True)
    assert sent == [0, 1, 2, 3]


def test_queue_aging_prevents_starvation():
    # aging knob: 1 priority point per queued second — a priority-0 request
    # waiting 10 s outranks a fresh priority-5 arrival
    q = GatewayQueue(capacity=8, ttl=60.0, aging=1.0)
    sent = []
    disp = lambda r: (sent.append(r.priority), 200)[1]
    q.offer(req(priority=0), MODEL, 0.0, dispatch=disp)
    q.offer(req(priority=5), MODEL, 10.0, dispatch=disp)
    q.drain(MODEL, 10.0, can_dispatch=lambda m: True)
    assert sent == [0, 5]
    # without aging the priority-5 request would have gone first
    q2 = GatewayQueue(capacity=8, ttl=60.0, aging=0.0)
    sent2 = []
    disp2 = lambda r: (sent2.append(r.priority), 200)[1]
    q2.offer(req(priority=0), MODEL, 0.0, dispatch=disp2)
    q2.offer(req(priority=5), MODEL, 10.0, dispatch=disp2)
    q2.drain(MODEL, 10.0, can_dispatch=lambda m: True)
    assert sent2 == [5, 0]


def test_queue_per_model_limits_from_spec():
    q = GatewayQueue(capacity=0)             # gateway-wide queuing disabled
    assert not q.enabled
    q.configure_model(MODEL, capacity=2, ttl=5.0)
    assert q.enabled
    assert q.offer(req(), MODEL, 0.0, dispatch=lambda r: 200)
    assert q.offer(req(), MODEL, 0.0, dispatch=lambda r: 200)
    assert not q.offer(req(), MODEL, 0.0, dispatch=lambda r: 200)
    assert not q.offer(req(), "other", 0.0, dispatch=lambda r: 200)
    assert len(q.expire(5.5)) == 2           # per-model TTL, not global 30 s
    q.configure_model(MODEL, None, None)
    assert not q.enabled


def test_deployment_queue_knobs_reach_gateway():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, est_load_time=30.0,
                queue_capacity=4, queue_ttl=120.0)
    gw = cp.web_gateway
    # no ready endpoint yet: requests ride the per-deployment queue
    client = ServingClient(cp, api_key="sk-test", default_model=MODEL)
    pend = client.completions(prompt=[1, 2, 3], max_tokens=4)
    assert pend.status == 202
    resp = pend.result(max_wait=200.0)
    assert resp.choices[0].finish_reason in ("stop", "length")


def test_reapply_same_policy_keeps_router_state():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, max_replicas=4,
                routing_policy="least_loaded", est_load_time=5.0)
    router = cp.web_gateway.router_for(MODEL)
    router.picks[("n", 1)] = 7          # routing history
    # a replicas-only re-apply must NOT rebuild the router (that would
    # wipe LeastLoaded's in-flight correction and herd the next burst)
    admin.apply(model=MODEL, replicas=2, max_replicas=4,
                routing_policy="least_loaded", est_load_time=5.0)
    assert cp.web_gateway.router_for(MODEL) is router
    # switching policy does swap it
    admin.apply(model=MODEL, replicas=2, max_replicas=4,
                routing_policy="round_robin", est_load_time=5.0)
    assert cp.web_gateway.router_for(MODEL).name == "round_robin"


def test_retry_after_honours_per_model_ttl():
    cp, admin = mk_admin()
    admin.apply(model=MODEL, replicas=1, est_load_time=500.0,
                queue_capacity=1, queue_ttl=90.0)
    gw = cp.web_gateway
    assert gw._retry_after(MODEL) == 90.0
    # gateway-wide queuing is off: other models hint the scale-up cooldown
    assert gw._retry_after("other") == cp.spec.services.retry_after_cooldown
    # queue full -> the 461 wire error carries the per-model TTL hint, and
    # the queued twin's expiry message reports the TTL that applied
    client = ServingClient(cp, api_key="sk-test", default_model=MODEL)
    first = client.completions(prompt=[1, 2, 3], max_tokens=4)
    assert first.status == 202
    with pytest.raises(APIStatusError) as ei:
        client.completions(prompt=[1, 2, 3], max_tokens=4)
    assert ei.value.error.retry_after == 90.0
    cp.run_until(cp.loop.now + 120.0)
    err = first.stream.error
    assert err is not None and "90s" in err.message
    assert err.retry_after == 90.0


def test_manifest_unknown_field_is_422():
    with pytest.raises(APIStatusError) as ei:
        ModelDeploymentSpec.from_dict({"model": MODEL, "replica": 3})
    assert ei.value.status == 422
    assert ei.value.error.param == "replica"


# ---------------------------------------------------------------------------
# satellite regression: SlurmSubmit sbatch coercion
# ---------------------------------------------------------------------------

def test_slurm_submit_coerces_sbatch_directives_after_spread():
    cp, _ = mk_admin()
    job_id = cp.slurm_submit.submit(
        "config_id=1,endpoint_job_id=1,model=m,version=1,"
        "gpus=2,nodes=1,partition=gpu,load=5.0,priority=7,bearer=tok-x")
    job = cp.slurm.jobs[job_id]
    # the **params spread used to overwrite the coerced ints with the raw
    # strings from the comma-delimited parameter string
    assert job.params["gpus"] == 2 and type(job.params["gpus"]) is int
    assert job.params["nodes"] == 1 and type(job.params["nodes"]) is int
    assert job.params["priority"] == 7 \
        and type(job.params["priority"]) is int
    assert job.params["partition"] == "gpu"
    assert job.priority == 7


def test_priority_class_orders_slurm_scheduling():
    # one free GPU slot, two pending jobs: the higher priority_class job
    # must be placed first even though it was submitted second
    cp, admin = mk_admin(num_nodes=1, gpus_per_node=1)
    cp.register_model(configs.get(MODEL))
    lo = cp.slurm_submit.submit("gpus=1,priority=0,model=x,version=1,"
                                "endpoint_job_id=0,bearer=t,load=1")
    hi = cp.slurm_submit.submit("gpus=1,priority=9,model=x,version=1,"
                                "endpoint_job_id=0,bearer=t,load=1")
    cp.run_until(10.0)
    assert cp.slurm.job_state(hi) == JobState.RUNNING
    assert cp.slurm.job_state(lo) == JobState.PENDING
