"""Randomised invariant tests for the paged-KV control plane — the
invariants a 1000-node deployment lives or dies by.

Formerly hypothesis property tests; rewritten as seeded-random pytest
parametrizations so the tier-1 suite collects with stdlib + pytest + numpy
only (the container does not ship hypothesis). Each seed regenerates the
same arbitrary op interleavings deterministically."""
import numpy as np
import pytest

from repro.engine.kv_cache import (BlockAllocator, OutOfBlocks, SequenceKV,
                                   chain_hash)


# ---------------------------------------------------------------------------
# allocator invariants under arbitrary alloc/free/fork interleavings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(40))
def test_allocator_never_leaks_or_double_frees(seed):
    rng = np.random.default_rng(seed)
    num_blocks = int(rng.integers(4, 65))
    n_ops = int(rng.integers(0, 200))
    alloc = BlockAllocator(num_blocks, 16, enable_prefix_caching=False)
    held: list[int] = []
    for _ in range(n_ops):
        op = rng.choice(["alloc", "free", "fork"])
        arg = int(rng.integers(0, 64))
        if op == "alloc":
            try:
                held.append(alloc.allocate())
            except OutOfBlocks:
                assert alloc.num_free() == 0
        elif op == "free" and held:
            alloc.free(held.pop(arg % len(held)))
        elif op == "fork" and held:
            idx = held[arg % len(held)]
            alloc.fork(idx)
            held.append(idx)
        alloc.check_invariants()
    for idx in held:
        alloc.free(idx)
    alloc.check_invariants()
    assert alloc.num_free() == num_blocks


@pytest.mark.parametrize("seed", range(25))
def test_sequence_blocks_match_token_count(seed):
    rng = np.random.default_rng(seed)
    block_size = int(rng.integers(2, 9))
    appends = rng.integers(1, 41, size=int(rng.integers(1, 13)))
    alloc = BlockAllocator(4096, block_size, enable_prefix_caching=False)
    seq = SequenceKV(alloc)
    total = 0
    for n in appends:
        seq.append_tokens(int(n))
        total += int(n)
        assert seq.num_tokens == total
        assert seq.num_blocks == -(-total // block_size)
    seq.release()
    alloc.check_invariants()
    assert alloc.num_free() == 4096


# ---------------------------------------------------------------------------
# prefix caching: correctness of content-addressed reuse
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(30))
def test_prefix_match_covers_exactly_common_complete_blocks(seed):
    rng = np.random.default_rng(seed)
    block_size = int(rng.integers(2, 9))
    len_a = int(rng.integers(0, 71))
    len_b = int(rng.integers(0, 71))
    master = rng.integers(1, 100, size=128).tolist()
    a = master[:len_a] + rng.integers(100, 200, size=4).tolist()
    b = master[:len_b] + rng.integers(200, 300, size=4).tolist()

    alloc = BlockAllocator(1024, block_size, enable_prefix_caching=True)
    sa = SequenceKV(alloc)
    assert sa.match_prefix(a) == 0          # cold cache
    sa.append_tokens(len(a), token_ids=a)

    sb = SequenceKV(alloc)
    covered = sb.match_prefix(b)
    common = 0
    for x, y in zip(a, b):
        if x != y:
            break
        common += 1
    # covered tokens are a complete-block prefix of the common prefix and
    # never include b's final token
    assert covered % block_size == 0
    assert covered <= common
    assert covered <= len(b) - 1
    # shared blocks must be the SAME physical blocks (ref-counted)
    for i in range(covered // block_size):
        assert sb.block_table[i] == sa.block_table[i]
        assert alloc.blocks[sb.block_table[i]].ref_count == 2
    sa.release()
    sb.release()
    alloc.check_invariants()


def test_extend_match_leapfrogs_newly_sealed_blocks():
    alloc = BlockAllocator(256, 4, enable_prefix_caching=True)
    master = list(range(1, 41))
    a = SequenceKV(alloc)
    a.match_prefix(master)
    b = SequenceKV(alloc)
    b.match_prefix(master)          # cold: 0
    assert b.num_tokens == 0
    a.append_tokens(20, token_ids=master)   # seals 5 blocks
    covered = b.extend_match(master)
    assert covered == 20
    assert b.block_table[:5] == a.block_table[:5]
    # final-token guard: can never cover the whole prompt
    c = SequenceKV(alloc)
    a.append_tokens(20, token_ids=master)   # seal all 10 blocks
    got = c.match_prefix(master)
    assert got <= len(master) - 1
    a.release(), b.release(), c.release()
    alloc.check_invariants()


def test_evictable_blocks_are_reused_before_eviction():
    alloc = BlockAllocator(4, 4, enable_prefix_caching=True)
    s = SequenceKV(alloc)
    toks = [1, 2, 3, 4, 5, 6, 7, 8]
    s.append_tokens(8, token_ids=toks)
    s.release()                      # sealed blocks go to evictable pool
    s2 = SequenceKV(alloc)
    assert s2.match_prefix(toks + [9]) == 8   # warm hit after release
    s2.release()
    # allocating everything evicts the cached blocks instead of failing
    held = [alloc.allocate() for _ in range(4)]
    for h in held:
        alloc.free(h)
    alloc.check_invariants()


def test_chain_hash_is_order_and_prefix_sensitive():
    h1 = chain_hash(None, (1, 2, 3, 4))
    h2 = chain_hash(None, (1, 2, 4, 3))
    assert h1 != h2
    # same block content under different parents must not collide
    assert chain_hash(h1, (5, 6)) != chain_hash(h2, (5, 6))
    # deterministic across calls (content-addressing requirement)
    assert h1 == chain_hash(None, (1, 2, 3, 4))
